"""Beyond-paper benchmark: AdaPT on a transformer LM (the paper only
evaluated CNNs). Trains the tiny LM config quantized vs float32 on the
synthetic stride-induction stream and reports loss + perf-model metrics —
evidence the technique transfers to the assigned LM architecture family.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict

import jax

from repro.config import load_config
from repro.core import perf_model
from repro.train import train_loop

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "experiments/paper")


def run(steps: int = 120) -> Dict:
    out: Dict = {"steps": steps}
    histories = {}
    for mode in ("off", "simulate"):
        cfg = load_config("tiny")
        cfg = dataclasses.replace(
            cfg,
            quant=dataclasses.replace(cfg.quant, mode=mode),
            optimizer=dataclasses.replace(cfg.optimizer, rop_patience=40),
            train=dataclasses.replace(cfg.train, steps=steps,
                                      adapt_interval=10, log_every=20))
        telemetry: list = []
        state, hist = train_loop.train(cfg, telemetry=telemetry,
                                       log=lambda s: None)
        histories[mode] = hist
        out[f"final_loss_{mode}"] = hist[-1]["loss"] if hist else None
        if mode == "simulate" and telemetry:
            last = telemetry[-1]
            wl = {k: float(jax.numpy.mean(v["wl"])) for k, v in last.items()}
            sp = {k: float(jax.numpy.mean(v["sp"])) for k, v in last.items()}
            out["avg_final_wl"] = round(sum(wl.values()) / len(wl), 2)
            out["avg_final_nonzero"] = round(sum(sp.values()) / len(sp), 3)
            # paper size model: sz = Σ sp·WL vs 32-bit dense
            out["SZ"] = round(sum(sp[k] * wl[k] for k in wl)
                              / (32.0 * len(wl)), 3)
    out["iso_loss_gap"] = (None if None in (out.get("final_loss_off"),
                                            out.get("final_loss_simulate"))
                           else round(out["final_loss_simulate"]
                                      - out["final_loss_off"], 4))
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "lm_bench.json"), "w") as f:
        json.dump(out, f, indent=1)
    print("== LM transfer benchmark (beyond-paper) ==")
    for k, v in out.items():
        print(f"  {k}: {v}")
    return out
