"""Ablations the paper lists as future work (§6): which AdaPT ingredients
matter? AlexNet × CIFAR10(synthetic), fixed steps/seed per variant.

  * init: TNVS (paper §3.1) vs plain He-normal
  * rounding: stochastic (paper §3.2) vs nearest
  * strategy: adaptive min/mean/max (eq. 5) vs pinned strategies
  * PushDown: on vs frozen ⟨8,4⟩ (no precision adaptation at all)
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List

import jax

from benchmarks.paper_tables import _cnn_cfg, _eval_acc
from repro.core.controller import snapshot
from repro.train import train_loop

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "experiments/paper")


def _variant(name: str, steps: int, batch: int):
    cfg = _cnn_cfg("alexnet", 10, steps, batch, quant=True)
    q = cfg.quant
    if name == "nearest_rounding":
        q = dataclasses.replace(q, stochastic_rounding=False)
    elif name == "strategy_min":
        q = dataclasses.replace(q, strategy="min")
    elif name == "strategy_max":
        q = dataclasses.replace(q, strategy="max")
    elif name == "frozen_8_4":
        # no precision switching at all: window never fills
        cfg = dataclasses.replace(cfg, train=dataclasses.replace(
            cfg.train, adapt_interval=10 ** 9))
    return dataclasses.replace(cfg, quant=q)


def run(steps: int = 150, batch: int = 64) -> List[Dict]:
    variants = ["adapt_full", "nearest_rounding", "strategy_min",
                "strategy_max", "frozen_8_4"]
    out = []
    for name in variants:
        cfg = _variant(name, steps, batch)
        telemetry: list = []
        state, hist = train_loop.train(cfg, telemetry=telemetry,
                                       log=lambda s: None)
        snap = snapshot(state["adapt"]) if state["adapt"]["tensors"] else {}
        avg_wl = (sum(float(t["wl"].mean()) for t in snap.values())
                  / max(len(snap), 1))
        rec = {"variant": name,
               "acc": round(_eval_acc(cfg, state), 4),
               "final_loss": round(hist[-1]["loss"], 4) if hist else None,
               "avg_final_wl": round(avg_wl, 2)}
        out.append(rec)
        print(f"[ablation] {name:18s} acc={rec['acc']:.3f} "
              f"loss={rec['final_loss']} avgWL={rec['avg_final_wl']}",
              flush=True)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "ablations.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    run()
