"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Runs, in order:
  1. paper tables 1–6 (AlexNet/ResNet20 × CIFAR10/100, f32 vs AdaPT,
     accuracy + the paper's analytical perf model),
  2. the beyond-paper LM transfer benchmark,
  3. the roofline table from any dry-run records present.

``--quick`` shrinks step counts (CI); ``--skip-cifar`` etc. select stages.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-cifar", action="store_true")
    ap.add_argument("--skip-lm", action="store_true")
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--skip-ablations", action="store_true")
    ap.add_argument("--skip-quant", action="store_true")
    args = ap.parse_args(argv)

    t0 = time.time()
    if not args.skip_quant:
        from benchmarks import quant_bench
        quant_bench.run(quick=args.quick)
    if not args.skip_cifar:
        from benchmarks import paper_tables
        paper_tables.run_all(quick=args.quick)
    if not args.skip_lm:
        from benchmarks import lm_bench
        lm_bench.run(steps=40 if args.quick else 120)
    if not args.skip_ablations:
        from benchmarks import ablations
        print("\n== Ablations (paper §6) ==")
        ablations.run(steps=60 if args.quick else 150)
    if not args.skip_roofline:
        from benchmarks import roofline_table
        roofline_table.main()
    print(f"\n[benchmarks] total {time.time() - t0:.0f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
