"""One benchmark per paper table (AdaPT, Kummer et al. 2021).

  T1/T2 — top-1 accuracy, AdaPT quantized vs float32 baseline
          (AlexNet & ResNet20 on CIFAR10/100)
  T3/T4 — MEM / SU (training) from the paper's analytical perf model
  T5    — final & average sparsity
  T6    — inference SU / SZ

The container is offline, so CIFAR is the deterministic synthetic stream in
``repro.data.synthetic`` (documented in EXPERIMENTS.md): per-class prototype
images + Gaussian noise. Absolute accuracies are not comparable to the
paper's, but every *relative* claim (quantized ≥ float32 accuracy, SU > 1,
SZ < 1, per-layer WL trajectories that move both ways) is evaluated exactly
as the paper evaluates it — same algorithm, same perf model (eq. 6–9).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List

import jax

from repro.config import Config
from repro.configs import get_smoke_config
from repro.core import perf_model
from repro.core.controller import snapshot
from repro.models import cnn
from repro.train import train_loop

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "experiments/paper")


def _cnn_cfg(arch: str, classes: int, steps: int, batch: int,
             quant: bool) -> Config:
    cfg = get_smoke_config(arch)
    cfg = dataclasses.replace(
        cfg,
        model=dataclasses.replace(cfg.model, vocab_size=classes),
        quant=dataclasses.replace(cfg.quant,
                                  mode="simulate" if quant else "off"),
        # l1 strong enough to sparsify within the run (the paper grid-
        # searched L1_decay per experiment; see §4.1.1)
        optimizer=dataclasses.replace(cfg.optimizer, rop_patience=50,
                                      l1=5e-5),
        train=dataclasses.replace(cfg.train, global_batch=batch, steps=steps,
                                  adapt_interval=10, log_every=25,
                                  seed=0),
    )
    return cfg


def _eval_acc(cfg: Config, state, steps: int = 8) -> float:
    """Held-out accuracy: fresh batches from a shifted seed."""
    from repro.data import synthetic
    _, fwd = cnn.MODELS[cfg.model.name.replace("-smoke", "")]
    params = state["params"]
    if cfg.quant.mode != "off":
        from repro.serve.engine import quantize_for_serving
        params = quantize_for_serving(params, state["adapt"], cfg.quant)
    accs = []
    for i in range(steps):
        b = synthetic.cifar_batch(cfg.model.vocab_size,
                                  cfg.train.global_batch, 10_000 + i,
                                  cfg.train.seed)
        logits, _ = fwd(params, state["stats"], b["images"], False)
        accs.append(float(cnn.accuracy(logits, b["labels"])))
    return sum(accs) / len(accs)


def _expand_telemetry(snaps: List[dict], interval: int
                      ) -> List[perf_model.StepTelemetry]:
    """Per-switch snapshots → per-step telemetry (wl/sp const in between)."""
    out = []
    for s in snaps:
        t = perf_model.StepTelemetry(
            wl={k: float(jax.numpy.mean(v["wl"])) for k, v in s.items()},
            sp={k: float(jax.numpy.mean(v["sp"])) for k, v in s.items()},
            lb={k: float(jax.numpy.mean(v["lb"])) for k, v in s.items()},
            r={k: float(jax.numpy.mean(v["res"])) for k, v in s.items()})
        out.extend([t] * interval)
    return out


def run_cifar_experiment(arch: str, classes: int, steps: int = 200,
                         batch: int = 64) -> Dict:
    """One (model × dataset) cell of tables 1–6."""
    results: Dict = {"arch": arch, "classes": classes, "steps": steps}

    # float32 baseline
    cfg_f32 = _cnn_cfg(arch, classes, steps, batch, quant=False)
    st_f32, hist_f32 = train_loop.train(cfg_f32, log=lambda s: None)
    results["acc_float32"] = _eval_acc(cfg_f32, st_f32)

    # AdaPT quantized
    cfg_q = _cnn_cfg(arch, classes, steps, batch, quant=True)
    telemetry: list = []
    st_q, hist_q = train_loop.train(cfg_q, telemetry=telemetry,
                                    log=lambda s: None)
    results["acc_adapt"] = _eval_acc(cfg_q, st_q)
    results["delta"] = results["acc_adapt"] - results["acc_float32"]

    # paper's analytical performance model (eq. 6–9). ops^l is the MAdds of
    # one *training step* (per-sample MAdds × batch size — eq. 8 sums per
    # step i, and the PushDown/PushUp overhead of eq. 6/7 is per *tensor*
    # per switch, amortized over the whole batch exactly as in the paper).
    interval = cfg_q.train.adapt_interval or cfg_q.quant.lb_lwr
    tel = _expand_telemetry(telemetry, interval)
    flat = jax.tree_util.tree_flatten_with_path(st_q["params"])[0]
    sizes = {"/".join(str(getattr(kk, "key", kk)) for kk in path): leaf.size
             for path, leaf in flat}
    ops = {k: perf_model.LayerOps(ops=v * batch,
                                  params=float(sizes.get(k, v)))
           for k, v in cnn.layer_madds(st_q["params"]).items()}
    summary = perf_model.summarize(ops, tel, accs=1)
    results.update({k: round(float(v), 4) for k, v in summary.items()})
    adapt_total = (perf_model.train_costs(ops, tel, 1)
                   + perf_model.adapt_overhead(ops, tel, 1))
    results["SU_vs_muppet"] = round(muppet_su(ops, len(tel), adapt_total), 2)

    # WL trajectory (fig. 3/4): per-layer wordlengths over switches
    results["wl_trajectory"] = [
        {k: float(jax.numpy.mean(s[k]["wl"])) for k in s} for s in telemetry]
    results["sp_trajectory"] = [
        {k: float(jax.numpy.mean(s[k]["sp"])) for k in s} for s in telemetry]
    results["final_loss_f32"] = hist_f32[-1]["loss"] if hist_f32 else None
    results["final_loss_adapt"] = hist_q[-1]["loss"] if hist_q else None
    return results


def muppet_su(cells_ops: Dict[str, perf_model.LayerOps], n_steps: int,
              adapt_costs: float) -> float:
    """SU vs MuPPET (paper tab. 3/4 SU³): MuPPET costs simulated with our
    perf model from the precision-switch schedule its paper reports
    (global block-FP WL 8→12→14→16, roughly 30/25/25/20% of training,
    float32 backward, no sparsity, no AdaPT overhead) — the same method the
    AdaPT paper used, since MuPPET's code base does not run (§4.2.1)."""
    schedule = [(0.30, 8), (0.25, 12), (0.25, 14), (0.20, 16)]
    tel = []
    for frac, wl in schedule:
        t = perf_model.StepTelemetry(
            wl={k: float(wl) for k in cells_ops},
            sp={k: 1.0 for k in cells_ops},
            lb={k: 25.0 for k in cells_ops},
            r={k: 50.0 for k in cells_ops})
        tel.extend([t] * max(int(frac * n_steps), 1))
    costs = perf_model.train_costs(cells_ops, tel, accs=1)
    return costs / max(adapt_costs, 1e-30)


def table_accuracy(cells: List[Dict]) -> str:
    lines = ["| model | classes | float32 | AdaPT | Δ |",
             "|---|---|---|---|---|"]
    for c in cells:
        lines.append(f"| {c['arch']} | {c['classes']} | "
                     f"{c['acc_float32']:.3f} | {c['acc_adapt']:.3f} | "
                     f"{c['delta']:+.3f} |")
    return "\n".join(lines)


def table_speedup(cells: List[Dict]) -> str:
    lines = ["| model | classes | MEM | SU_train | SU_infer | SZ | SU³ (vs MuPPET) |",
             "|---|---|---|---|---|---|---|"]
    for c in cells:
        lines.append(f"| {c['arch']} | {c['classes']} | {c['MEM']:.2f} | "
                     f"{c['SU_train']:.2f} | {c['SU_infer']:.2f} | "
                     f"{c['SZ']:.2f} | {c.get('SU_vs_muppet', 0):.2f} |")
    return "\n".join(lines)


def table_sparsity(cells: List[Dict]) -> str:
    lines = ["| model | classes | final sparsity | avg sparsity |",
             "|---|---|---|---|"]
    for c in cells:
        sp_fin = 1.0 - c["avg_sp"]
        sp_avg = (1.0 - sum(sum(s.values()) / max(len(s), 1)
                            for s in c["sp_trajectory"])
                  / max(len(c["sp_trajectory"]), 1)
                  if c["sp_trajectory"] else 0.0)
        lines.append(f"| {c['arch']} | {c['classes']} | {sp_fin:.3f} | "
                     f"{sp_avg:.3f} |")
    return "\n".join(lines)


def run_all(steps: int = 200, batch: int = 64, quick: bool = False) -> Dict:
    if quick:
        steps, batch = 60, 32
    cells = []
    for arch in ("alexnet", "resnet20"):
        for classes in (10, 100):
            print(f"[paper] {arch} × CIFAR{classes} "
                  f"({steps} steps, f32 + AdaPT)...", flush=True)
            cells.append(run_cifar_experiment(arch, classes, steps, batch))
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "cifar_cells.json"), "w") as f:
        json.dump(cells, f, indent=1)
    out = {
        "table_1_2_accuracy": table_accuracy(cells),
        "table_3_4_speedup": table_speedup(cells),
        "table_5_sparsity": table_sparsity(cells),
        "cells": cells,
    }
    print("\n== Paper tables 1/2 (top-1 accuracy) ==")
    print(out["table_1_2_accuracy"])
    print("\n== Paper tables 3/4/6 (MEM / SU / SZ, perf model eq. 6-9) ==")
    print(out["table_3_4_speedup"])
    print("\n== Paper table 5 (sparsity) ==")
    print(out["table_5_sparsity"])
    return out
