"""Microbenchmark: the precision-machinery fast path.

Hot spots, each measured XLA-reference vs fused-Pallas:

  * ``quantize`` — the per-step quantize of every weight tensor (alg. 1).
    Baseline: jax.random noise materialized in HBM + 5-op XLA quantize.
    Fused: ``sr_quantize_fused`` — noise drawn in-kernel, one pass.
  * ``quantize_stacked`` — the per-layer-stacked regime ("blocks" leaves,
    heterogeneous (L,)-vector ⟨WL,FL⟩). Baseline: broadcast-⟨WL,FL⟩ XLA
    quantize with materialized noise (the pre-PR-2 fallback this path
    replaced). Fused: one ``sr_quantize_fused_stacked`` launch.
  * ``quantize_sharded`` — the shard_map-wrapped kernel on a real mesh
    (recorded only when >1 device is visible, e.g. under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4``). Baseline:
    noise + sharding-constraint XLA path. Fused: per-shard folded seeds,
    zero collectives (asserted on the compiled HLO).
  * ``switch`` — PushDown's EDF ladder (alg. 3). Baseline: 18 vmapped
    quantize probes + 36 scatter-add histograms. Fused: one
    ``edf_ladder_hists`` launch + KL/argmin epilogue.
  * ``train_step`` — the END-TO-END jitted tiny-config train step across
    the dense-dispatch regimes (pure XLA / PR-4 flash-only / packed words
    into the fxp kernels / quantize-in-prologue), with per-variant jaxpr
    structure facts — the HBM-round-trip win is measured, not asserted.
  * ``fwd_bwd`` (``--skip-fwd-bwd`` to omit) — the DIFFERENTIATED forward:
    fxp_matmul and flash attention, forward-only and value_and_grad, the
    Pallas custom-VJP route vs XLA autodiff of the jnp oracle. Structure
    facts recorded: the grad jaxpr contains the forward AND both backward
    Pallas kernels (no silent XLA fallback under differentiation). Rows
    cover block-aligned shapes AND prime/non-divisible ones (flagged
    ``tail_masked``): the latter run tail-masked partial boundary blocks,
    while aligned shapes trace to the unmasked kernels — comparing the
    pairs pins the tail-mask overhead on aligned shapes at ~0.

  * ``serve_degraded`` — decode-step latency at each AdaBits serving
    level (WL 8/6/4, the overload-degradation ladder) plus the decode
    compile count across level swaps — the zero-recompile claim behind
    precision degradation under load, measured on the real batcher.

Besides wall times the run records the *structural* facts the perf claims
rest on, read off the jaxprs (these hold on any backend):

  * the fused quantize issues ≤ 2 param-sized HBM transfers per tensor
    (kernel input + output) and materializes NO noise operand;
  * the fused precision switch contains zero scatter-adds.

Wall-clock numbers on a CPU container run the kernels in Pallas interpret
mode and are NOT indicative of TPU performance (interpret mode evaluates
the kernel op-by-op); they are recorded for trajectory only, flagged by
``"backend"`` in the output. Emits ``BENCH_quant.json``.
"""
from __future__ import annotations

import argparse
import inspect
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro import jaxpr_tools
from repro.core import fixed_point as fxp, pushdown
from repro.kernels import fxp_matmul as _fm
from repro.kernels import ops

SIZES = [(512, 512), (1024, 2048), (2048, 4096)]
SIZES_QUICK = [(256, 256), (512, 512), (512, 1024)]
STACKED_SIZES = [(4, 512, 512), (12, 512, 1024)]
STACKED_SIZES_QUICK = [(4, 128, 256), (8, 256, 256)]


def _time(fn, reps: int = 5) -> float:
    jax.block_until_ready(fn())                   # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps


# ---------------------------------------------------------------------------
# jaxpr structure readers (shared walker: repro.jaxpr_tools)


def _fused_structure(fn, x, *args, min_size: int | None = None) -> dict:
    """Param-sized HBM operands of the fused kernel call + noise audit.
    ``min_size`` overrides the "param-sized" threshold (the shard_map-
    wrapped kernel sees per-shard blocks, not the global tensor)."""
    n = min_size if min_size is not None else x.size
    jaxpr = jax.make_jaxpr(fn)(x, *args).jaxpr
    transfers = 0
    for e in jaxpr_tools.iter_eqns(jaxpr):
        if e.primitive.name == "pallas_call":
            transfers = sum(getattr(v.aval, "size", 0) >= n
                            for v in list(e.invars) + list(e.outvars))
    return {"noise_materialized":
            bool(jaxpr_tools.rng_eqns_of_size(jaxpr, n)),
            "kernel_param_sized_hbm_transfers": transfers}


def _quantize_structure(n: int) -> dict:
    return _fused_structure(
        lambda v, s: ops.sr_quantize_fused(v, s, 8, 4, use_pallas=True),
        jnp.zeros((n,), jnp.float32), jnp.int32(0))


def _switch_structure(n: int) -> dict:
    w = jnp.zeros((n,), jnp.float32)

    def count_scatters(use_pallas):
        jaxpr = jax.make_jaxpr(lambda v: pushdown.push_down(
            v, jnp.int32(100), r_upr=150, eps_kl=1e-2,
            use_pallas=use_pallas))(w).jaxpr
        return jaxpr_tools.count_primitives(jaxpr, "scatter")

    return {"baseline_scatter_adds": count_scatters(False),
            "fused_scatter_adds": count_scatters(True)}


# ---------------------------------------------------------------------------


def bench_quantize(sizes, reps: int) -> list:
    rows = []
    for shape in sizes:
        x = jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32)
        key = jax.random.PRNGKey(1)
        wl, fl = jnp.int32(8), jnp.int32(4)

        @jax.jit
        def xla_path(v, k, wl=wl, fl=fl):
            u = jax.random.uniform(k, v.shape, jnp.float32)
            return fxp.quantize(v, wl, fl, u=u)

        @jax.jit
        def fused_path(v, s, wl=wl, fl=fl):
            return ops.sr_quantize_fused(v, s, wl, fl, use_pallas=True)

        t_xla = _time(lambda: xla_path(x, key), reps=reps)
        t_fused = _time(lambda: fused_path(x, jnp.int32(7)), reps=reps)
        rows.append({
            "shape": list(shape),
            "elements": int(x.size),
            "xla_ms": t_xla * 1e3,
            "fused_pallas_ms": t_fused * 1e3,
            **_quantize_structure(int(x.size)),
        })
        print(f"  quantize {shape}: xla {t_xla * 1e3:8.2f} ms | "
              f"fused {t_fused * 1e3:8.2f} ms")
    return rows


def bench_quantize_stacked(sizes, reps: int) -> list:
    """The per-layer-stacked regime: heterogeneous (L,)-vector ⟨WL,FL⟩.
    The XLA baseline is exactly the pre-PR-2 fallback (broadcast precision
    + materialized noise); the fused path is one stacked-kernel launch."""
    rows = []
    for shape in sizes:
        L = shape[0]
        x = jax.random.normal(jax.random.PRNGKey(3), shape, jnp.float32)
        key = jax.random.PRNGKey(4)
        wl = jnp.asarray(4 + (jnp.arange(L) % 12), jnp.int32)   # WL 4..15
        fl = jnp.asarray(2 + (jnp.arange(L) % 9), jnp.int32)
        bshape = (L,) + (1,) * (len(shape) - 1)

        @jax.jit
        def xla_path(v, k, wl=wl.reshape(bshape), fl=fl.reshape(bshape)):
            u = jax.random.uniform(k, v.shape, jnp.float32)
            return fxp.quantize(v, wl, fl, u=u)

        @jax.jit
        def fused_path(v, s, wl=wl, fl=fl):
            return ops.sr_quantize_fused(v, s, wl, fl, use_pallas=True)

        t_xla = _time(lambda: xla_path(x, key), reps=reps)
        t_fused = _time(lambda: fused_path(x, jnp.int32(7)), reps=reps)
        rows.append({
            "shape": list(shape),
            "layers": L,
            "elements": int(x.size),
            "xla_ms": t_xla * 1e3,
            "fused_pallas_ms": t_fused * 1e3,
            **_fused_structure(
                lambda v, s: ops.sr_quantize_fused(v, s, wl, fl,
                                                   use_pallas=True),
                x, jnp.int32(0)),
        })
        print(f"  stacked  {shape}: xla {t_xla * 1e3:8.2f} ms | "
              f"fused {t_fused * 1e3:8.2f} ms")
    return rows


def bench_quantize_sharded(reps: int) -> dict:
    """The shard_map-wrapped fused kernel on a real mesh vs the XLA
    noise+constraint path. Needs >1 visible device (CPU: run under
    XLA_FLAGS=--xla_force_host_platform_device_count=N)."""
    ndev = jax.device_count()
    if ndev < 2:
        print("  sharded: skipped (1 device)")
        return {"skipped": "needs >1 device "
                           "(XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=N)"}
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    mesh = Mesh(np.array(jax.devices()).reshape(ndev), ("data",))
    sh = NamedSharding(mesh, P("data", None))
    shape = (128 * ndev, 1024)
    x = jax.device_put(
        jax.random.normal(jax.random.PRNGKey(5), shape, jnp.float32), sh)
    key = jax.random.PRNGKey(6)

    @jax.jit
    def xla_path(v, k):
        u = jax.random.uniform(k, v.shape, jnp.float32)
        u = jax.lax.with_sharding_constraint(u, sh)
        return jax.lax.with_sharding_constraint(
            fxp.quantize(v, jnp.int32(8), jnp.int32(4), u=u), sh)

    @jax.jit
    def fused_path(v, s):
        return ops.sr_quantize_fused(v, s, 8, 4, use_pallas=True,
                                     sharding=sh)

    t_xla = _time(lambda: xla_path(x, key), reps=reps)
    t_fused = _time(lambda: fused_path(x, jnp.int32(9)), reps=reps)
    hlo = fused_path.lower(x, jnp.int32(9)).compile().as_text()
    row = {
        "devices": ndev,
        "shape": list(shape),
        "elements": int(x.size),
        "xla_ms": t_xla * 1e3,
        "fused_pallas_ms": t_fused * 1e3,
        "fused_hlo_all_gather_free": "all-gather" not in hlo,
        **_fused_structure(
            lambda v, s: ops.sr_quantize_fused(v, s, 8, 4, use_pallas=True,
                                               sharding=sh),
            x, jnp.int32(0), min_size=int(x.size) // ndev),
    }
    print(f"  sharded  {shape} x{ndev}dev: xla {t_xla * 1e3:8.2f} ms | "
          f"fused {t_fused * 1e3:8.2f} ms | "
          f"all-gather-free={row['fused_hlo_all_gather_free']}")
    return row


def bench_switch(reps: int, sample: int = 65536) -> dict:
    w = jax.random.normal(jax.random.PRNGKey(2), (sample,), jnp.float32)

    base = jax.jit(lambda v: pushdown.push_down(
        v, jnp.int32(100), r_upr=150, eps_kl=1e-2))
    fused = jax.jit(lambda v: pushdown.push_down(
        v, jnp.int32(100), r_upr=150, eps_kl=1e-2, use_pallas=True))

    t_base = _time(lambda: base(w), reps=reps)
    t_fused = _time(lambda: fused(w), reps=reps)
    a, b = base(w), fused(w)
    assert (int(a[0]), int(a[1])) == (int(b[0]), int(b[1])), \
        "fused PushDown diverged from the reference"
    print(f"  switch ({sample} sample): scatter {t_base * 1e3:8.2f} ms | "
          f"ladder {t_fused * 1e3:8.2f} ms")
    return {
        "edf_sample": sample,
        "scatter_ms": t_base * 1e3,
        "ladder_kernel_ms": t_fused * 1e3,
        "wl_fl_parity": True,
        **_switch_structure(sample),
    }


# Aligned shapes tile the default blocks evenly (the masking helpers are
# static no-ops — tail-mask overhead on these rows must stay ~0); the
# prime/non-divisible shapes run tail-masked partial boundary blocks (the
# shapes the pre-masking wrappers refused or blew up to whole-dim blocks).
MATMUL_SIZES = [(512, 1024, 512), (1024, 2048, 1024), (509, 1031, 509)]
MATMUL_SIZES_QUICK = [(128, 256, 128), (256, 512, 256), (300, 520, 260)]
ATTN_SIZES = [(2, 512, 8, 2, 64), (1, 1024, 8, 2, 64),   # (B,S,H,Hkv,D)
              (1, 509, 8, 2, 64)]
ATTN_SIZES_QUICK = [(1, 128, 4, 2, 32), (2, 256, 4, 2, 64),
                    (1, 300, 4, 2, 32)]

# Blocks the fwd_bwd section runs with, used to label rows as tail-masked:
# ops.fxp_matmul exposes no block args, so read the (bm, bn, bk) defaults
# off fxp_matmul_vjp — the exact entry point ops.fxp_matmul dispatches to
# under use_pallas — so label and execution can't drift.
_MATMUL_BLOCKS = tuple(
    inspect.signature(_fm.fxp_matmul_vjp).parameters[name].default
    for name in ("bm", "bn", "bk"))
_ATTN_BLOCK = 256                                         # bq = bk (passed)


def _has_tail(dim: int, block: int) -> bool:
    b = min(block, dim)
    return dim % b != 0


def _grad_structure(fn, *args) -> dict:
    """Fwd + bwd Pallas kernels present in the differentiated jaxpr."""
    jaxpr = jax.make_jaxpr(jax.grad(fn))(*args).jaxpr
    names = jaxpr_tools.pallas_kernel_names(jaxpr)
    return {"pallas_calls_in_grad": len(names),
            "grad_kernels": sorted(set(names))}


def bench_fwd_bwd(matmul_sizes, attn_sizes, reps: int) -> dict:
    """The differentiated train forward: Pallas custom-VJP vs XLA oracle.

    The loss is QUADRATIC in the output and timed via value_and_grad: a
    linear loss's cotangent is a constant, which XLA folds away on the
    baseline (its 'backward' would measure nothing) while the opaque
    custom_vjp can't be folded — a phantom slowdown."""
    from repro.kernels import ref
    matmul_rows = []
    for m, k, n in matmul_sizes:
        x = jax.random.normal(jax.random.PRNGKey(7), (m, k), jnp.float32)
        wq = jax.random.randint(jax.random.PRNGKey(8), (k, n), -128, 128,
                                jnp.int8)
        s = jnp.float32(1 / 64)

        def fwd(v, use_pallas):
            out = ops.fxp_matmul(v, wq, s, use_pallas=use_pallas)
            return 0.5 * jnp.sum(out * out)

        g_pal = jax.jit(jax.value_and_grad(lambda v: fwd(v, True)))
        g_xla = jax.jit(jax.value_and_grad(lambda v: fwd(v, False)))
        f_pal = jax.jit(lambda v: fwd(v, True))
        f_xla = jax.jit(lambda v: fwd(v, False))
        bm, bn, bk = _MATMUL_BLOCKS
        row = {
            "shape": [m, k, n],
            "tail_masked": (_has_tail(m, bm) or _has_tail(n, bn)
                            or _has_tail(k, bk)),
            "xla_fwd_ms": _time(lambda: f_xla(x), reps=reps) * 1e3,
            "pallas_fwd_ms": _time(lambda: f_pal(x), reps=reps) * 1e3,
            "xla_fwd_bwd_ms": _time(lambda: g_xla(x), reps=reps) * 1e3,
            "pallas_fwd_bwd_ms": _time(lambda: g_pal(x), reps=reps) * 1e3,
            **_grad_structure(lambda v: fwd(v, True), x),
        }
        matmul_rows.append(row)
        print(f"  matmul   {(m, k, n)}"
              f"{' [tail]' if row['tail_masked'] else ''}: fwd+bwd xla "
              f"{row['xla_fwd_bwd_ms']:8.2f} ms | pallas "
              f"{row['pallas_fwd_bwd_ms']:8.2f} ms")

    attn_rows = []
    for B, S, H, Hkv, D in attn_sizes:
        ks = jax.random.split(jax.random.PRNGKey(9), 3)
        q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
        kv = [jax.random.normal(k_, (B, S, Hkv, D), jnp.float32)
              for k_ in ks[1:]]

        def fwd(v, use_pallas):
            out = ops.attention(v, *kv, causal=True, use_pallas=use_pallas,
                                bq=_ATTN_BLOCK, bk=_ATTN_BLOCK)
            return 0.5 * jnp.sum(out * out)

        def ref_fwd(v):
            out = ref.ref_attention(v, *kv, causal=True)
            return 0.5 * jnp.sum(out * out)

        g_pal = jax.jit(jax.value_and_grad(lambda v: fwd(v, True)))
        g_xla = jax.jit(jax.value_and_grad(ref_fwd))
        f_pal = jax.jit(lambda v: fwd(v, True))
        f_xla = jax.jit(ref_fwd)
        row = {
            "shape": [B, S, H, Hkv, D],
            "tail_masked": _has_tail(S, _ATTN_BLOCK),
            "xla_fwd_ms": _time(lambda: f_xla(q), reps=reps) * 1e3,
            "pallas_fwd_ms": _time(lambda: f_pal(q), reps=reps) * 1e3,
            "xla_fwd_bwd_ms": _time(lambda: g_xla(q), reps=reps) * 1e3,
            "pallas_fwd_bwd_ms": _time(lambda: g_pal(q), reps=reps) * 1e3,
            **_grad_structure(lambda v: fwd(v, True), q),
        }
        attn_rows.append(row)
        print(f"  attn     {(B, S, H, Hkv, D)}"
              f"{' [tail]' if row['tail_masked'] else ''}: fwd+bwd xla "
              f"{row['xla_fwd_bwd_ms']:8.2f} ms | pallas "
              f"{row['pallas_fwd_bwd_ms']:8.2f} ms")
    return {"matmul": matmul_rows, "attention": attn_rows}


def bench_train_step(reps: int) -> dict:
    """END-TO-END jitted train step on the tiny config, the measurement
    behind the dense-wiring claim: with container_dtype="int8_packed" +
    use_pallas the model's dense layers consume quantized words directly
    (fwd + dx + dw Pallas per layer, zero dequantized-weight XLA matmuls),
    and dense_prologue additionally drops the q8 HBM round trip (the
    sr-quantize launches for dense leaves disappear — words are drawn in
    the matmul prologue). Variants:

      * xla                — use_pallas off (pure XLA reference)
      * pr4_flash_only     — use_pallas on, float32 container: the PR 4
                             state (flash kernels, dense layers still XLA
                             on a dequantized HBM copy)
      * dense_materialized — packed words streamed into the fxp kernels
      * dense_prologue     — quantize fused into the matmul prologue

    Structure facts per variant are read off the traced step."""
    import dataclasses
    from repro.config import load_config
    from repro.train import train_loop

    variants = {
        "xla": (False, False, "int8_packed"),
        "pr4_flash_only": (True, False, "float32"),
        "dense_materialized": (True, False, "int8_packed"),
        "dense_prologue": (True, True, "int8_packed"),
    }
    rows = {}
    for name, (use_pallas, prologue, container) in variants.items():
        cfg = load_config("tiny", overrides=[
            f"quant.container_dtype={container}", "quant.max_wl=8",
            "quant.init_wl=8", "quant.init_fl=4"])
        cfg = dataclasses.replace(
            cfg,
            quant=dataclasses.replace(cfg.quant, use_pallas=use_pallas,
                                      dense_prologue=prologue),
            train=dataclasses.replace(cfg.train, adapt_interval=1000))
        state = train_loop.init_state(cfg)
        batch = train_loop.make_batch(cfg, 0)
        step = jax.jit(train_loop.make_train_step(cfg))
        t = _time(lambda: step(state, batch)[1]["loss"], reps=reps)
        jaxpr = jax.make_jaxpr(train_loop.make_train_step(cfg))(
            state, batch).jaxpr
        cnt = lambda s: jaxpr_tools.count_pallas_calls(jaxpr, s)
        rows[name] = {
            "step_ms": t * 1e3,
            "dense_pallas_fwd": cnt("_fxp_matmul_kernel")
                + cnt("_fxp_qmatmul_kernel"),
            "dense_pallas_dx": cnt("_matmul_dx_kernel")
                + cnt("_matmul_qdx_kernel"),
            "dense_pallas_dw": cnt("_matmul_dw_kernel"),
            # q8-materializing quantize launches (prologue drops the
            # dense-leaf ones; the embed table keeps its own)
            "sr_quantize_launches": cnt("_sr_fused"),
        }
        print(f"  train_step {name:20s}: {t * 1e3:8.2f} ms | "
              f"dense fwd/dx/dw {rows[name]['dense_pallas_fwd']}/"
              f"{rows[name]['dense_pallas_dx']}/"
              f"{rows[name]['dense_pallas_dw']} | "
              f"sr-launches {rows[name]['sr_quantize_launches']}")
    return rows


def bench_degraded_decode(reps: int) -> dict:
    """Decode-step latency at each AdaBits serving level (WL 8/6/4): the
    continuous batcher's degraded-precision rows. One batcher, one jitted
    decode; the qparams tree is swapped per level — the recorded
    ``decode_compile_count`` pins the zero-recompile claim (all levels
    share one treedef and one compiled executable)."""
    from repro.config import load_config
    from repro.serve.engine import quantize_serving_levels
    from repro.serve.scheduler import ContinuousBatcher
    from repro.train import train_loop

    cfg = load_config("tiny")
    state = train_loop.init_state(cfg)
    adapt = state["adapt"]
    levels = (8, 6, 4)
    qlevels = quantize_serving_levels(state["params"], adapt, cfg.quant,
                                      levels)
    if list(qlevels) != list(levels):       # no controller state: one row
        levels = tuple(qlevels)
    cb = ContinuousBatcher(cfg, state["params"], adapt, slots=4,
                           max_context=64)
    tokens = jnp.zeros((len(cb.slots),), jnp.int32)
    positions = jnp.zeros((len(cb.slots),), jnp.int32)
    rows = {}
    for wl in levels:
        qp = qlevels[wl]
        t = _time(lambda: cb._decode(qp, tokens, cb.caches, positions)[0],
                  reps=reps)
        rows[f"wl{wl}"] = {"decode_ms": t * 1e3}
        print(f"  decode   WL={wl}: {t * 1e3:8.2f} ms/step "
              f"({len(cb.slots)} slots)")
    rows["decode_compile_count"] = int(cb._decode._cache_size())
    print(f"  decode   compile count across levels: "
          f"{rows['decode_compile_count']} (recompile-free swap)")
    return rows


def run(quick: bool = False, out: str = "BENCH_quant.json",
        skip_fwd_bwd: bool = False) -> dict:
    print("\n== Precision-machinery microbenchmark ==")
    backend = jax.default_backend()
    if backend != "tpu":
        print(f"  [note] backend={backend}: Pallas runs in interpret mode; "
              "wall times are not TPU-indicative (structure checks are).")
    sizes = SIZES_QUICK if quick else SIZES
    stacked_sizes = STACKED_SIZES_QUICK if quick else STACKED_SIZES
    reps = 3 if quick else 5
    result = {
        "backend": backend,
        "interpret_mode": backend != "tpu",
        "quantize": bench_quantize(sizes, reps),
        "quantize_stacked": bench_quantize_stacked(stacked_sizes, reps),
        "quantize_sharded": bench_quantize_sharded(reps),
        "switch": bench_switch(reps, sample=16384 if quick else 65536),
        "fwd_bwd": ({"skipped": "--skip-fwd-bwd"} if skip_fwd_bwd else
                    bench_fwd_bwd(
                        MATMUL_SIZES_QUICK if quick else MATMUL_SIZES,
                        ATTN_SIZES_QUICK if quick else ATTN_SIZES, reps)),
        "train_step": bench_train_step(2 if quick else 3),
        "serve_degraded": bench_degraded_decode(2 if quick else 3),
    }
    with open(out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"  wrote {out}")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_quant.json")
    ap.add_argument("--skip-fwd-bwd", action="store_true",
                    help="omit the differentiated fwd+bwd matmul/attention "
                         "section (interpret-mode bwd kernels are slow on "
                         "CPU)")
    args = ap.parse_args(argv)
    run(quick=args.quick, out=args.out, skip_fwd_bwd=args.skip_fwd_bwd)
    return 0


if __name__ == "__main__":
    sys.exit(main())
