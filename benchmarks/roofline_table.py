"""§Roofline table: read experiments/dryrun/*.json and emit the per-cell
three-term roofline with bottleneck + usefulness ratio."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from repro.config import SHAPES, load_config
from repro.configs import assigned_archs
from repro.roofline import analysis

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN", "experiments/dryrun")


def load_records(multi_pod: bool = False) -> List[Dict]:
    suffix = "2pod" if multi_pod else "1pod"
    out = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*_{suffix}.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def table(multi_pod: bool = False) -> str:
    recs = load_records(multi_pod)
    order = {a: i for i, a in enumerate(assigned_archs())}
    sorder = {s: i for i, s in enumerate(SHAPES)}
    recs.sort(key=lambda r: (order.get(r["arch"], 99),
                             sorder.get(r["shape"], 9)))
    chips = 512 if multi_pod else 256
    lines = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
        "bottleneck | model/HLO flops | status |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | "
                         f"skipped: {r['reason']} |")
            continue
        if r["status"] != "compiled":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | "
                         f"{r['status']} |")
            continue
        t = analysis.roofline_terms(r)
        useful = ""
        if r.get("kind") == "train":
            try:
                cfg = load_config(r["arch"], r["shape"])
                useful = f"{analysis.usefulness(r, cfg, chips):.2f}"
            except Exception:
                useful = "?"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s'] * 1e3:.1f} | "
            f"{t['memory_s'] * 1e3:.1f} | {t['collective_s'] * 1e3:.1f} | "
            f"{t['bottleneck'].replace('_s', '')} | {useful} | compiled |")
    return "\n".join(lines)


def summary(multi_pod: bool = False) -> Dict:
    recs = load_records(multi_pod)
    return {
        "compiled": sum(r["status"] == "compiled" for r in recs),
        "skipped": sum(r["status"] == "skipped" for r in recs),
        "failed": sum(r["status"] not in ("compiled", "skipped")
                      for r in recs),
    }


def main():
    for mp in (False, True):
        recs = load_records(mp)
        if not recs:
            print(f"[roofline] no records for "
                  f"{'2pod' if mp else '1pod'} in {DRYRUN_DIR}")
            continue
        print(f"\n== Roofline ({'2-pod/512' if mp else '1-pod/256'} chips) — "
              f"{summary(mp)} ==")
        print(table(mp))


if __name__ == "__main__":
    main()
