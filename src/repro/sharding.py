"""Logical-axis sharding: model code annotates tensors with *logical* axes
("batch", "seq", "heads", "ff", "experts", "vocab", "embed"); the launcher
installs a rule set mapping logical → physical mesh axes for the current
(mesh × input-shape) combination. Outside any rule context every annotation
is a no-op, so models run unmodified on a single CPU device.

Rule sets (see launch/mesh.py):
  train/prefill/decode: batch → ("pod","data"), heads/ff/experts/vocab → "model"
  long-context decode:  seq(kv) → ("pod","data")  (batch=1 → shard the cache)
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jax.Array

_RULES: contextvars.ContextVar[Optional[Tuple[Mesh, Dict[str, tuple]]]] = \
    contextvars.ContextVar("repro_sharding_rules", default=None)


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: Dict[str, tuple]):
    """rules: logical axis name -> tuple of mesh axis names (or ())."""
    token = _RULES.set((mesh, dict(rules)))
    try:
        yield
    finally:
        _RULES.reset(token)


def active() -> bool:
    return _RULES.get() is not None


def current_mesh() -> Optional[Mesh]:
    ctx = _RULES.get()
    return ctx[0] if ctx else None


def spec(*logical_axes: Optional[str]) -> Optional[P]:
    """PartitionSpec for a tensor whose dims carry these logical names."""
    ctx = _RULES.get()
    if ctx is None:
        return None
    _, rules = ctx
    parts = []
    used = set()
    for name in logical_axes:
        axes = rules.get(name, ()) if name else ()
        # a mesh axis may appear at most once in a spec
        axes = tuple(a for a in axes if a not in used)
        used.update(axes)
        if not axes:
            parts.append(None)
        elif len(axes) == 1:
            parts.append(axes[0])
        else:
            parts.append(tuple(axes))
    return P(*parts)


def shard(x: Array, *logical_axes: Optional[str]) -> Array:
    """Annotate ``x`` (len(logical_axes) == x.ndim) if rules are active."""
    ctx = _RULES.get()
    if ctx is None:
        return x
    mesh, _ = ctx
    s = spec(*logical_axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s))


def named_sharding(*logical_axes: Optional[str]) -> Optional[NamedSharding]:
    ctx = _RULES.get()
    if ctx is None:
        return None
    mesh, _ = ctx
    return NamedSharding(mesh, spec(*logical_axes))


def shard_map(f, mesh: Mesh, *, axis_names, in_specs, out_specs,
              check: bool = False):
    """Version-compat shard_map, manual ONLY over ``axis_names`` (auto over
    the rest of the mesh). Newer JAX spells this ``jax.shard_map(...,
    axis_names=..., check_vma=...)``; the pinned jaxlib only ships
    ``jax.experimental.shard_map.shard_map(..., auto=..., check_rep=...)``.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, axis_names=set(axis_names),
                             in_specs=in_specs, out_specs=out_specs,
                             check_vma=check)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check, auto=auto)


def spec_dim_axes(spec, ndim: int) -> Tuple[tuple, ...]:
    """Per-dim tuples of mesh-axis names of a PartitionSpec, padded to
    ``ndim`` dims (PartitionSpecs may be shorter than the rank; missing and
    ``None`` entries mean replicated)."""
    entries = tuple(spec) if spec is not None else ()
    entries = entries[:ndim] + (None,) * (ndim - len(entries))
    return tuple(() if e is None else ((e,) if isinstance(e, str)
                                       else tuple(e)) for e in entries)


def shard_grid(shape, spec, mesh: Mesh) -> Optional[Tuple[int, ...]]:
    """Per-dim shard counts of an array of ``shape`` under (spec, mesh), or
    None when a sharded dim does not divide evenly over its mesh axes —
    shard_map needs equal blocks, so uneven leaves are ineligible for the
    shard_map-wrapped kernels."""
    grid = []
    for d, axes in enumerate(spec_dim_axes(spec, len(shape))):
        k = 1
        for a in axes:
            k *= mesh.shape[a]
        if shape[d] % k:
            return None
        grid.append(k)
    return tuple(grid)


def strip_axes(rules: Dict[str, tuple], axes) -> Dict[str, tuple]:
    """Rules with the given mesh axes removed (e.g. inside a shard_map that
    is manual over 'pod', constraints may only name auto axes)."""
    out = {}
    for k, v in rules.items():
        out[k] = tuple(a for a in v if a not in axes) \
            if isinstance(v, tuple) else v
    return out


def flag(name: str):
    """Read an out-of-band flag stashed in the rules dict (keys starting
    with '#'); None outside a rules context. Used for mesh-dependent compute
    policies (e.g. '#tp_reduce_bf16') that model code must see at trace
    time without threading config through every layer call."""
    ctx = _RULES.get()
    if ctx is None:
        return None
    return ctx[1].get(name)


def axis_size(logical: str) -> int:
    """Product of mesh-axis sizes a logical axis maps to (1 outside rules).
    Model code uses this to pick shard-aligned internal layouts (e.g. the
    MoE group-limited dispatch groups)."""
    ctx = _RULES.get()
    if ctx is None:
        return 1
    mesh, rules = ctx
    n = 1
    for a in rules.get(logical, ()):
        n *= mesh.shape[a]
    return n
