"""Pallas TPU kernels for AdaPT's compute hot spots (+ ops dispatch, ref oracles)."""
