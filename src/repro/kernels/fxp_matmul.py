"""Pallas TPU kernel: fixed-point (int8-stored) matmul with fused dequant.

The AdaPT steady state keeps most layers at WL ≤ 8 (training starts at ⟨8,4⟩
and PushDown pushes down), so the hot matmul is
    y = x @ (wq · 2^-FL) (+ bias)
with wq int8. Doing dequant-then-matmul in XLA materializes a full f32/bf16
copy of the weights in HBM every step; this kernel streams int8 weight tiles
into VMEM (4× less HBM traffic than f32, 2× less than bf16) and dequantizes
in-register on the way into the MXU.

Block scheme: grid (⌈M/bm⌉, ⌈N/bn⌉, ⌈K/bk⌉), K innermost so the f32
accumulator tile lives in a VMEM scratch across the K loop; MXU-aligned
128-multiples preferred but NOT required — partial boundary blocks are
tail-masked in-kernel (``_mask_tail``: Pallas pads them with garbage/NaN),
so any ⟨M,K,N⟩ runs with the requested block clamp and bounded VMEM.

A full-integer variant (``int8_matmul``) takes int8 activations too and
accumulates in int32 — the v5e MXU's 2× int8 throughput path; used for
serving (W8A8) and benchmarked in §Perf.

Both ops also come in differentiable form (``fxp_matmul_vjp`` /
``int8_matmul_vjp``): ``jax.custom_vjp`` rules whose backward passes are
themselves Pallas kernels, so the differentiated training forward never
falls back to a dequantized HBM weight copy either.

  * dx = dy @ (wq·scale)ᵀ  — ``_matmul_dx_kernel`` streams the SAME int8
    weight tiles the forward reads, just with a transposed index map
    ((j, n) instead of (k, j)); dequant stays in-register.
  * dw = xᵀ @ dy           — ``_matmul_dw_kernel``, f32 VMEM accumulation;
    its contraction against wq yields the scale cotangent
    dscale = Σ dw∘wq (= Σ dy∘(x@wq), XLA's reassociation of the same sum).
  * dwq is float0: the int8 words are non-differentiable storage — the
    straight-through path to the f32 master runs through the quantize,
    not through the matmul words.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import sr_quantize as _sq
from repro.kernels._compat import tpu_compiler_params

Array = jax.Array


def _clamp_block(b: int, d: int) -> int:
    """Block size for a dim of true extent d: the requested b, clamped.
    Non-divisible boundaries are fine — every gridded kernel here
    tail-masks its padded lanes in-register (Pallas pads partial boundary
    blocks with garbage/NaN, and out-of-range boundary writes are
    dropped), so grids stay ``pl.cdiv`` with VMEM bounded by the
    *requested* block for ANY dim, primes included. O(1): the old
    divisor-scan fallback (largest divisor ≤ b, else the whole dim — a
    VMEM hazard for large prime-ish dims) is gone."""
    return min(b, d)


def _mask_tail(x: Array, axis: int, pid, dim: int) -> Array:
    """Zero the garbage-padding tail of a boundary block along ``axis``.

    ``dim`` is the true (unpadded) extent of the axis; the block extent is
    read off ``x`` itself and ``pid`` is the grid index along that axis.
    Statically a no-op when the grid tiles ``dim`` evenly, so aligned
    shapes trace to exactly the unmasked kernel (zero overhead)."""
    b = x.shape[axis]
    if dim % b == 0:
        return x
    idx = b * pid + jax.lax.broadcasted_iota(jnp.int32, x.shape, axis)
    return jnp.where(idx < dim, x, jnp.zeros_like(x))


def float0_like(x: Array) -> np.ndarray:
    """The cotangent for a non-differentiable integer operand (custom_vjp
    requires an explicit float0 array for int primals)."""
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


def _fxp_matmul_kernel(x_ref, w_ref, scale_ref, o_ref, acc_ref, *, nk: int,
                       dims: tuple):
    M, K, N = dims
    i, j, ik = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # K is contracted: garbage in EITHER operand's K tail would poison
    # every output element (0·NaN = NaN), so both tails go to exact zero.
    x = _mask_tail(x_ref[...].astype(jnp.float32), 1, ik, K)
    w = _mask_tail(w_ref[...].astype(jnp.float32), 0, ik, K)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _done():
        # M/N tails only pollute out-of-range output lanes (dropped on the
        # boundary write) — zero-fill them anyway so the block never holds
        # garbage.
        out = acc_ref[...] * scale_ref[0, 0]
        out = _mask_tail(_mask_tail(out, 0, i, M), 1, j, N)
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret",
                                             "out_dtype"))
def fxp_matmul(x: Array, wq: Array, scale: Array, *, bm: int = 256,
               bn: int = 256, bk: int = 512, out_dtype=None,
               interpret: bool = False) -> Array:
    """y = x @ (wq * scale).  x: (M,K) float; wq: (K,N) int8; scale: () f32.

    Any ⟨M,K,N⟩ is accepted (primes included): partial boundary blocks are
    tail-masked in-kernel, so blocks stay the requested clamp and VMEM
    stays bounded."""
    M, K = x.shape
    K2, N = wq.shape
    assert K == K2, (x.shape, wq.shape)
    out_dtype = out_dtype or x.dtype
    bm, bn, bk = _clamp_block(bm, M), _clamp_block(bn, N), _clamp_block(bk, K)
    grid = (pl.cdiv(M, bm), pl.cdiv(N, bn), pl.cdiv(K, bk))
    kernel = functools.partial(_fxp_matmul_kernel, nk=grid[2],
                               dims=(M, K, N))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(x, wq, scale.reshape(1, 1).astype(jnp.float32))


def _int8_matmul_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, nk: int,
                        dims: tuple):
    M, K, N = dims
    i, j, ik = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # int8 padding is arbitrary garbage words — zero both K tails so the
    # int32 accumulation over the tail is exactly 0.
    x = _mask_tail(x_ref[...], 1, ik, K)
    w = _mask_tail(w_ref[...], 0, ik, K)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(ik == nk - 1)
    def _done():
        out = acc_ref[...].astype(jnp.float32) * s_ref[0, 0]
        out = _mask_tail(_mask_tail(out, 0, i, M), 1, j, N)
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def int8_matmul(xq: Array, wq: Array, sx: Array, sw: Array, *, bm: int = 256,
                bn: int = 256, bk: int = 512, interpret: bool = False) -> Array:
    """W8A8 path: (xq @ wq) * (sx*sw); int32 MXU accumulation, f32 out.
    Accepts any ⟨M,K,N⟩ — partial boundary blocks are tail-masked."""
    M, K = xq.shape
    K2, N = wq.shape
    assert K == K2, (xq.shape, wq.shape)
    bm, bn, bk = _clamp_block(bm, M), _clamp_block(bn, N), _clamp_block(bk, K)
    grid = (pl.cdiv(M, bm), pl.cdiv(N, bn), pl.cdiv(K, bk))
    kernel = functools.partial(_int8_matmul_kernel, nk=grid[2],
                               dims=(M, K, N))
    s = (sx.astype(jnp.float32) * sw.astype(jnp.float32)).reshape(1, 1)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(xq, wq, s)


# ---------------------------------------------------------------------------
# Backward kernels


def _matmul_dx_kernel(dy_ref, w_ref, scale_ref, dx_ref, acc_ref, *, nn: int,
                      dims: tuple):
    """dx tile = Σ_n dy(i,n) @ w(j,n)ᵀ — the weight tile is the forward's
    int8 (K,N) array read through a transposed index map, dequantized
    in-register; no transposed/dequantized weight copy ever exists in HBM."""
    M, K, N = dims
    i, j, n = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # N is the contracted dim here — zero both N tails before the MXU.
    dy = _mask_tail(dy_ref[...].astype(jnp.float32), 1, n, N)
    w = _mask_tail(w_ref[...].astype(jnp.float32), 1, n, N)
    acc_ref[...] += jax.lax.dot_general(
        dy, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(n == nn - 1)
    def _done():
        out = acc_ref[...] * scale_ref[0, 0]
        out = _mask_tail(_mask_tail(out, 0, i, M), 1, j, K)
        dx_ref[...] = out.astype(dx_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret",
                                             "out_dtype"))
def matmul_dx(dy: Array, wq: Array, scale: Array, *, bm: int = 256,
              bn: int = 256, bk: int = 512, out_dtype=None,
              interpret: bool = False) -> Array:
    """dx = dy @ (wq * scale)ᵀ.  dy: (M,N); wq: (K,N) int8; out (M,K)."""
    M, N = dy.shape
    K, N2 = wq.shape
    assert N == N2, (dy.shape, wq.shape)
    out_dtype = out_dtype or dy.dtype
    bm, bk, bn = _clamp_block(bm, M), _clamp_block(bk, K), _clamp_block(bn, N)
    grid = (pl.cdiv(M, bm), pl.cdiv(K, bk), pl.cdiv(N, bn))
    kernel = functools.partial(_matmul_dx_kernel, nn=grid[2],
                               dims=(M, K, N))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, n: (i, n)),
            pl.BlockSpec((bk, bn), lambda i, j, n: (j, n)),   # transposed map
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j, n: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, K), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bk), jnp.float32)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(dy, wq, scale.reshape(1, 1).astype(jnp.float32))


def _matmul_dw_kernel(x_ref, dy_ref, dw_ref, acc_ref, *, nm: int,
                      dims: tuple):
    M, K, N = dims
    i, j, m = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(m == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # M is the contracted dim here — zero both M tails before the MXU.
    x = _mask_tail(x_ref[...].astype(jnp.float32), 0, m, M)
    dy = _mask_tail(dy_ref[...].astype(jnp.float32), 0, m, M)
    acc_ref[...] += jax.lax.dot_general(
        x, dy, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(m == nm - 1)
    def _done():
        dw_ref[...] = _mask_tail(_mask_tail(acc_ref[...], 0, i, K), 1, j, N)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul_dw(x: Array, dy: Array, *, bm: int = 256, bn: int = 256,
              bk: int = 512, interpret: bool = False) -> Array:
    """dw = xᵀ @ dy in f32 (VMEM scratch accumulation over the M loop).
    x: (M,K); dy: (M,N); out (K,N) f32."""
    M, K = x.shape
    M2, N = dy.shape
    assert M == M2, (x.shape, dy.shape)
    bk, bn, bm = _clamp_block(bk, K), _clamp_block(bn, N), _clamp_block(bm, M)
    grid = (pl.cdiv(K, bk), pl.cdiv(N, bn), pl.cdiv(M, bm))
    kernel = functools.partial(_matmul_dw_kernel, nm=grid[2],
                               dims=(M, K, N))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, m: (m, i)),
            pl.BlockSpec((bm, bn), lambda i, j, m: (m, j)),
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda i, j, m: (i, j)),
        out_shape=jax.ShapeDtypeStruct((K, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bk, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(x, dy)


# ---------------------------------------------------------------------------
# Quantize-prologue variant: the matmul consumes the float MASTER weight
# plus ⟨FL, seed, mode⟩ and quantizes each tile in-register on the way into
# the MXU — the int8 words exist only in VMEM, never in HBM (closes the
# "fused quantize-into-matmul" ROADMAP item: no q8 write+read-back round
# trip on freshly re-quantized layers). The noise is the PORTABLE
# counter-hash stream over the weight element's flat index (k·N + n), NOT
# the hardware PRNG: the words must be a pure function of ⟨seed, element⟩
# so the forward launch and the dx recompute — which tile the same weight
# differently — draw bit-identical words. For an unstacked (K, N) leaf
# this is the exact stream of ``sr_quantize_fused_int8``'s PORTABLE mode,
# so prologue and materialized words match bit-for-bit under interpret /
# CPU CI (tests/test_dense_path.py pins this); on compiled TPU the
# materialized kernel uses the hardware PRNG, so there the two dispatches
# agree in distribution, not bits.
#
# ``mode`` selects rounding at trace-free runtime: 1 = stochastic (SR),
# 0 = round-to-nearest-even (matches the XLA ``jnp.round`` packed path
# exactly, ties included — serving and SR-off training stay bit-compatible
# across dispatches).


def _quantize_w_tile(w: Array, fl, seed, mode, k0, n0, n_dim: int) -> Array:
    """In-register ⟨8,FL⟩ quantize of one (bk, bn) master-weight tile to
    int8-range fixed-point words (f32 values, int8 range by clip)."""
    scale = _sq._pow2i(fl)
    s = w * scale
    r = jax.lax.broadcasted_iota(jnp.uint32, w.shape, 0)
    c = jax.lax.broadcasted_iota(jnp.uint32, w.shape, 1)
    idx = (k0.astype(jnp.uint32) + r) * jnp.uint32(n_dim) \
        + n0.astype(jnp.uint32) + c
    u = _sq.uniform_from_index(seed, idx)
    f = jnp.floor(s)
    q_sr = f + (u < (s - f)).astype(jnp.float32)
    q = jnp.where(mode == 1, q_sr, jnp.round(s))
    return jnp.clip(q, -128.0, 127.0)


def _fxp_qmatmul_kernel(ctl_ref, x_ref, w_ref, o_ref, acc_ref, *, nk: int,
                        dims: tuple):
    M, K, N = dims
    i, j, ik = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    fl, seed, mode = ctl_ref[0, 0], ctl_ref[0, 1], ctl_ref[0, 2]
    x = _mask_tail(x_ref[...].astype(jnp.float32), 1, ik, K)
    w = w_ref[...].astype(jnp.float32)
    bk, bn = w.shape
    q = _quantize_w_tile(w, fl, seed, mode, k0=ik * bk, n0=j * bn, n_dim=N)
    # K is contracted: garbage padding quantizes to garbage words (NaN
    # survives the clip), so the K tails of BOTH operands go to exact zero.
    q = _mask_tail(q, 0, ik, K)
    acc_ref[...] += jax.lax.dot_general(
        x, q, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _done():
        out = acc_ref[...] * _sq._pow2i(-fl)
        out = _mask_tail(_mask_tail(out, 0, i, M), 1, j, N)
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret",
                                             "out_dtype"))
def fxp_qmatmul(x: Array, w: Array, seed: Array, fl: Array, mode: Array, *,
                bm: int = 256, bn: int = 256, bk: int = 512, out_dtype=None,
                interpret: bool = False) -> Array:
    """y = x @ (Q⟨8,fl⟩(w) · 2^-fl), quantizing ``w`` in the matmul
    prologue. x: (M,K) float; w: (K,N) float MASTER; seed/fl/mode: int32
    scalars (mode 1 = SR via the portable index-hash stream, 0 = RTN).
    Any ⟨M,K,N⟩ is accepted — partial boundary blocks are tail-masked."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    out_dtype = out_dtype or x.dtype
    bm, bn, bk = _clamp_block(bm, M), _clamp_block(bn, N), _clamp_block(bk, K)
    grid = (pl.cdiv(M, bm), pl.cdiv(N, bn), pl.cdiv(K, bk))
    kernel = functools.partial(_fxp_qmatmul_kernel, nk=grid[2],
                               dims=(M, K, N))
    ctl = jnp.stack([jnp.asarray(fl), jnp.asarray(seed),
                     jnp.asarray(mode)]).astype(jnp.int32).reshape(1, 3)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(ctl, x, w)


def _matmul_qdx_kernel(ctl_ref, dy_ref, w_ref, dx_ref, acc_ref, *, nn: int,
                       dims: tuple):
    """dx = dy @ Q(w)ᵀ·2^-fl — the prologue's dx recompute: the SAME master
    tiles the forward read (transposed index map), re-quantized in-register
    with the SAME index-hash words, so fwd and bwd agree on every bit of
    the weight draw without any HBM word copy existing."""
    M, K, N = dims
    i, j, n = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    fl, seed, mode = ctl_ref[0, 0], ctl_ref[0, 1], ctl_ref[0, 2]
    dy = _mask_tail(dy_ref[...].astype(jnp.float32), 1, n, N)
    w = w_ref[...].astype(jnp.float32)
    bk, bn = w.shape
    q = _quantize_w_tile(w, fl, seed, mode, k0=j * bk, n0=n * bn, n_dim=N)
    # N is the contracted dim here — zero both N tails before the MXU.
    q = _mask_tail(q, 1, n, N)
    acc_ref[...] += jax.lax.dot_general(
        dy, q, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(n == nn - 1)
    def _done():
        out = acc_ref[...] * _sq._pow2i(-fl)
        out = _mask_tail(_mask_tail(out, 0, i, M), 1, j, K)
        dx_ref[...] = out.astype(dx_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret",
                                             "out_dtype"))
def matmul_qdx(dy: Array, w: Array, seed: Array, fl: Array, mode: Array, *,
               bm: int = 256, bn: int = 256, bk: int = 512, out_dtype=None,
               interpret: bool = False) -> Array:
    """dx = dy @ (Q⟨8,fl⟩(w)·2^-fl)ᵀ.  dy: (M,N); w: (K,N) float master."""
    M, N = dy.shape
    K, N2 = w.shape
    assert N == N2, (dy.shape, w.shape)
    out_dtype = out_dtype or dy.dtype
    bm, bk, bn = _clamp_block(bm, M), _clamp_block(bk, K), _clamp_block(bn, N)
    grid = (pl.cdiv(M, bm), pl.cdiv(K, bk), pl.cdiv(N, bn))
    kernel = functools.partial(_matmul_qdx_kernel, nn=grid[2],
                               dims=(M, K, N))
    ctl = jnp.stack([jnp.asarray(fl), jnp.asarray(seed),
                     jnp.asarray(mode)]).astype(jnp.int32).reshape(1, 3)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bm, bn), lambda i, j, n: (i, n)),
            pl.BlockSpec((bk, bn), lambda i, j, n: (j, n)),   # transposed map
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j, n: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, K), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bk), jnp.float32)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(ctl, dy, w)


# ---------------------------------------------------------------------------
# custom_vjp rules


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fxp_matmul_diff(cfg, x, wq, scale):
    bm, bn, bk, out_dtype, interpret = cfg
    return fxp_matmul(x, wq, scale, bm=bm, bn=bn, bk=bk,
                      out_dtype=out_dtype, interpret=interpret)


def _fxp_matmul_diff_fwd(cfg, x, wq, scale):
    return _fxp_matmul_diff(cfg, x, wq, scale), (x, wq, scale)


def _fxp_matmul_diff_bwd(cfg, res, dy):
    bm, bn, bk, _, interpret = cfg
    x, wq, scale = res
    dx = matmul_dx(dy, wq, scale, bm=bm, bn=bn, bk=bk,
                   out_dtype=x.dtype, interpret=interpret)
    dw = matmul_dw(x, dy, bm=bm, bn=bn, bk=bk, interpret=interpret)
    dscale = (jnp.sum(dw * wq.astype(jnp.float32))
              .reshape(scale.shape).astype(scale.dtype))
    return dx, float0_like(wq), dscale


_fxp_matmul_diff.defvjp(_fxp_matmul_diff_fwd, _fxp_matmul_diff_bwd)


def fxp_matmul_vjp(x: Array, wq: Array, scale: Array, *, bm: int = 256,
                   bn: int = 256, bk: int = 512, out_dtype=None,
                   interpret: bool = False) -> Array:
    """Differentiable :func:`fxp_matmul`: same forward kernel, Pallas
    backward (``matmul_dx`` / ``matmul_dw``)."""
    return _fxp_matmul_diff((bm, bn, bk, out_dtype, interpret),
                            x, wq, jnp.asarray(scale, jnp.float32))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _int8_matmul_diff(cfg, xq, wq, sx, sw):
    bm, bn, bk, interpret = cfg
    return int8_matmul(xq, wq, sx, sw, bm=bm, bn=bn, bk=bk,
                       interpret=interpret)


def _int8_matmul_diff_fwd(cfg, xq, wq, sx, sw):
    return _int8_matmul_diff(cfg, xq, wq, sx, sw), (xq, wq, sx, sw)


def _int8_matmul_diff_bwd(cfg, res, dy):
    bm, bn, bk, interpret = cfg
    xq, wq, sx, sw = res
    # Recompute-based backward: both operands are int8 words (float0
    # cotangents), so the only gradients are the two scales. The raw int32
    # accumulator is regenerated by the forward kernel at unit scale.
    acc = int8_matmul(xq, wq, jnp.float32(1.0), jnp.float32(1.0),
                      bm=bm, bn=bn, bk=bk, interpret=interpret)
    g0 = jnp.sum(dy.astype(jnp.float32) * acc)
    dsx = (g0 * sw.astype(jnp.float32)).reshape(sx.shape).astype(sx.dtype)
    dsw = (g0 * sx.astype(jnp.float32)).reshape(sw.shape).astype(sw.dtype)
    return float0_like(xq), float0_like(wq), dsx, dsw


_int8_matmul_diff.defvjp(_int8_matmul_diff_fwd, _int8_matmul_diff_bwd)


def int8_matmul_vjp(xq: Array, wq: Array, sx: Array, sw: Array, *,
                    bm: int = 256, bn: int = 256, bk: int = 512,
                    interpret: bool = False) -> Array:
    """Differentiable :func:`int8_matmul` (scale cotangents only; the int8
    words are non-differentiable storage)."""
    return _int8_matmul_diff((bm, bn, bk, interpret), xq, wq,
                             jnp.asarray(sx, jnp.float32),
                             jnp.asarray(sw, jnp.float32))


# ---------------------------------------------------------------------------
# Dense-layer rules: the model's TRAINING matmul. Unlike ``fxp_matmul_vjp``
# (whose weight cotangent is only contracted into dscale), these carry the
# straight-through gradient of paper alg. 1: the full dw = xᵀ@dy lands on
# the MASTER copy (wref for materialized words, wm for the prologue), so
# the optimizer step is exactly the one the XLA dequant-then-dot path
# produces — while the forward/dx stream int8 tiles through the MXU.


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fxp_dense_diff(cfg, x, wq, scale, wref):
    del wref    # gradient receiver only: never read, so its zeros are DCE'd
    bm, bn, bk, out_dtype, interpret, _ = cfg
    return fxp_matmul(x, wq, scale, bm=bm, bn=bn, bk=bk,
                      out_dtype=out_dtype, interpret=interpret)


def _fxp_dense_diff_fwd(cfg, x, wq, scale, wref):
    return _fxp_dense_diff(cfg, x, wq, scale, wref), (x, wq, scale)


def _fxp_dense_diff_bwd(cfg, res, dy):
    bm, bn, bk, _, interpret, wref_dtype = cfg
    x, wq, scale = res
    dx = matmul_dx(dy, wq, scale, bm=bm, bn=bn, bk=bk,
                   out_dtype=x.dtype, interpret=interpret)
    dw = matmul_dw(x, dy, bm=bm, bn=bn, bk=bk, interpret=interpret)
    # straight-through: the whole weight cotangent routes to the master
    # receiver; the scale is controller state (2^-FL), not a trainable —
    # its cotangent is zero, matching fixed_point.dequant_packed's rule.
    return dx, float0_like(wq), jnp.zeros_like(scale), dw.astype(wref_dtype)


_fxp_dense_diff.defvjp(_fxp_dense_diff_fwd, _fxp_dense_diff_bwd)


def fxp_dense_vjp(x: Array, wq: Array, scale: Array, wref: Array, *,
                  bm: int = 256, bn: int = 256, bk: int = 512,
                  out_dtype=None, interpret: bool = False) -> Array:
    """Differentiable dense layer over MATERIALIZED int8 words: forward is
    :func:`fxp_matmul`, dx streams the same int8 tiles (``matmul_dx``), and
    dw = xᵀ@dy (``matmul_dw``) lands on ``wref`` — the straight-through
    path to the master copy. ``scale`` may be () or (1, 1) (a scan-sliced
    per-layer 2^-FL); ``wref`` is never read (its cotangent is the output)."""
    return _fxp_dense_diff((bm, bn, bk, out_dtype, interpret,
                            jnp.dtype(wref.dtype)), x, wq, scale, wref)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fxp_qdense_diff(cfg, x, w, seed, fl, mode):
    bm, bn, bk, out_dtype, interpret = cfg
    return fxp_qmatmul(x, w, seed, fl, mode, bm=bm, bn=bn, bk=bk,
                       out_dtype=out_dtype, interpret=interpret)


def _fxp_qdense_diff_fwd(cfg, x, w, seed, fl, mode):
    return _fxp_qdense_diff(cfg, x, w, seed, fl, mode), (x, w, seed, fl, mode)


def _fxp_qdense_diff_bwd(cfg, res, dy):
    bm, bn, bk, _, interpret = cfg
    x, w, seed, fl, mode = res
    dx = matmul_qdx(dy, w, seed, fl, mode, bm=bm, bn=bn, bk=bk,
                    out_dtype=x.dtype, interpret=interpret)
    dw = matmul_dw(x, dy, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return (dx, dw.astype(w.dtype), float0_like(seed), float0_like(fl),
            float0_like(mode))


_fxp_qdense_diff.defvjp(_fxp_qdense_diff_fwd, _fxp_qdense_diff_bwd)


def fxp_qdense_vjp(x: Array, w: Array, seed: Array, fl: Array, mode: Array,
                   *, bm: int = 256, bn: int = 256, bk: int = 512,
                   out_dtype=None, interpret: bool = False) -> Array:
    """Differentiable quantize-prologue dense layer: forward is
    :func:`fxp_qmatmul` (master in, words only ever in VMEM), dx is
    :func:`matmul_qdx` (same index-hash words, recomputed in-register), and
    the straight-through dw = xᵀ@dy lands directly on ``w`` — which IS the
    master copy, so no quantized weight tensor exists in HBM at all."""
    return _fxp_qdense_diff(
        (bm, bn, bk, out_dtype, interpret), x, w,
        jnp.asarray(seed, jnp.int32), jnp.asarray(fl, jnp.int32),
        jnp.asarray(mode, jnp.int32))
