"""Pallas TPU kernel: fixed-point (int8-stored) matmul with fused dequant.

The AdaPT steady state keeps most layers at WL ≤ 8 (training starts at ⟨8,4⟩
and PushDown pushes down), so the hot matmul is
    y = x @ (wq · 2^-FL) (+ bias)
with wq int8. Doing dequant-then-matmul in XLA materializes a full f32/bf16
copy of the weights in HBM every step; this kernel streams int8 weight tiles
into VMEM (4× less HBM traffic than f32, 2× less than bf16) and dequantizes
in-register on the way into the MXU.

Block scheme: grid (M/bm, N/bn, K/bk), K innermost so the f32 accumulator
tile lives in a VMEM scratch across the K loop; MXU-aligned 128-multiples.

A full-integer variant (``int8_matmul``) takes int8 activations too and
accumulates in int32 — the v5e MXU's 2× int8 throughput path; used for
serving (W8A8) and benchmarked in §Perf.

Both ops also come in differentiable form (``fxp_matmul_vjp`` /
``int8_matmul_vjp``): ``jax.custom_vjp`` rules whose backward passes are
themselves Pallas kernels, so the differentiated training forward never
falls back to a dequantized HBM weight copy either.

  * dx = dy @ (wq·scale)ᵀ  — ``_matmul_dx_kernel`` streams the SAME int8
    weight tiles the forward reads, just with a transposed index map
    ((j, n) instead of (k, j)); dequant stays in-register.
  * dw = xᵀ @ dy           — ``_matmul_dw_kernel``, f32 VMEM accumulation;
    its contraction against wq yields the scale cotangent
    dscale = Σ dw∘wq (= Σ dy∘(x@wq), XLA's reassociation of the same sum).
  * dwq is float0: the int8 words are non-differentiable storage — the
    straight-through path to the f32 master runs through the quantize,
    not through the matmul words.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import tpu_compiler_params

Array = jax.Array


def _fit_block(b: int, d: int) -> int:
    """Largest usable block ≤ b that tiles d EVENLY. Pallas pads partial
    boundary blocks with garbage/NaN rather than zeros in interpret mode,
    so a block size that does not divide the dim would silently poison the
    accumulation; every wrapper here therefore refuses to create partial
    blocks. Preference order: the requested b, else the largest divisor of
    d that is ≤ b (keeps VMEM bounded for large non-aligned dims), else —
    when d is so prime-ish the best divisor is a degenerate sliver — the
    whole dim as one block."""
    b = min(b, d)
    if d % b == 0:
        return b
    best = max(c for c in range(1, b + 1) if d % c == 0)
    return best if best >= max(8, b // 8) else d


def float0_like(x: Array) -> np.ndarray:
    """The cotangent for a non-differentiable integer operand (custom_vjp
    requires an explicit float0 array for int primals)."""
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


def _fxp_matmul_kernel(x_ref, w_ref, scale_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)          # int8 -> f32 in-register
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        o_ref[...] = (acc_ref[...] * scale_ref[0, 0]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret",
                                             "out_dtype"))
def fxp_matmul(x: Array, wq: Array, scale: Array, *, bm: int = 256,
               bn: int = 256, bk: int = 512, out_dtype=None,
               interpret: bool = False) -> Array:
    """y = x @ (wq * scale).  x: (M,K) float; wq: (K,N) int8; scale: () f32."""
    M, K = x.shape
    K2, N = wq.shape
    assert K == K2, (x.shape, wq.shape)
    out_dtype = out_dtype or x.dtype
    bm, bn, bk = _fit_block(bm, M), _fit_block(bn, N), _fit_block(bk, K)
    grid = (pl.cdiv(M, bm), pl.cdiv(N, bn), pl.cdiv(K, bk))
    kernel = functools.partial(_fxp_matmul_kernel, nk=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(x, wq, scale.reshape(1, 1).astype(jnp.float32))


def _int8_matmul_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        o_ref[...] = (acc_ref[...].astype(jnp.float32)
                      * s_ref[0, 0]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def int8_matmul(xq: Array, wq: Array, sx: Array, sw: Array, *, bm: int = 256,
                bn: int = 256, bk: int = 512, interpret: bool = False) -> Array:
    """W8A8 path: (xq @ wq) * (sx*sw); int32 MXU accumulation, f32 out."""
    M, K = xq.shape
    _, N = wq.shape
    bm, bn, bk = _fit_block(bm, M), _fit_block(bn, N), _fit_block(bk, K)
    grid = (pl.cdiv(M, bm), pl.cdiv(N, bn), pl.cdiv(K, bk))
    kernel = functools.partial(_int8_matmul_kernel, nk=grid[2])
    s = (sx.astype(jnp.float32) * sw.astype(jnp.float32)).reshape(1, 1)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(xq, wq, s)


# ---------------------------------------------------------------------------
# Backward kernels


def _matmul_dx_kernel(dy_ref, w_ref, scale_ref, dx_ref, acc_ref, *, nn: int):
    """dx tile = Σ_n dy(i,n) @ w(j,n)ᵀ — the weight tile is the forward's
    int8 (K,N) array read through a transposed index map, dequantized
    in-register; no transposed/dequantized weight copy ever exists in HBM."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    dy = dy_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)           # int8 -> f32 in-register
    acc_ref[...] += jax.lax.dot_general(
        dy, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nn - 1)
    def _done():
        dx_ref[...] = (acc_ref[...] * scale_ref[0, 0]).astype(dx_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret",
                                             "out_dtype"))
def matmul_dx(dy: Array, wq: Array, scale: Array, *, bm: int = 256,
              bn: int = 256, bk: int = 512, out_dtype=None,
              interpret: bool = False) -> Array:
    """dx = dy @ (wq * scale)ᵀ.  dy: (M,N); wq: (K,N) int8; out (M,K)."""
    M, N = dy.shape
    K, N2 = wq.shape
    assert N == N2, (dy.shape, wq.shape)
    out_dtype = out_dtype or dy.dtype
    bm, bk, bn = _fit_block(bm, M), _fit_block(bk, K), _fit_block(bn, N)
    grid = (pl.cdiv(M, bm), pl.cdiv(K, bk), pl.cdiv(N, bn))
    kernel = functools.partial(_matmul_dx_kernel, nn=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, n: (i, n)),
            pl.BlockSpec((bk, bn), lambda i, j, n: (j, n)),   # transposed map
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j, n: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, K), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bk), jnp.float32)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(dy, wq, scale.reshape(1, 1).astype(jnp.float32))


def _matmul_dw_kernel(x_ref, dy_ref, dw_ref, acc_ref, *, nm: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    dy = dy_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        x, dy, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nm - 1)
    def _done():
        dw_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul_dw(x: Array, dy: Array, *, bm: int = 256, bn: int = 256,
              bk: int = 512, interpret: bool = False) -> Array:
    """dw = xᵀ @ dy in f32 (VMEM scratch accumulation over the M loop).
    x: (M,K); dy: (M,N); out (K,N) f32."""
    M, K = x.shape
    M2, N = dy.shape
    assert M == M2, (x.shape, dy.shape)
    bk, bn, bm = _fit_block(bk, K), _fit_block(bn, N), _fit_block(bm, M)
    grid = (pl.cdiv(K, bk), pl.cdiv(N, bn), pl.cdiv(M, bm))
    kernel = functools.partial(_matmul_dw_kernel, nm=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, m: (m, i)),
            pl.BlockSpec((bm, bn), lambda i, j, m: (m, j)),
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda i, j, m: (i, j)),
        out_shape=jax.ShapeDtypeStruct((K, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bk, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(x, dy)


# ---------------------------------------------------------------------------
# custom_vjp rules


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fxp_matmul_diff(cfg, x, wq, scale):
    bm, bn, bk, out_dtype, interpret = cfg
    return fxp_matmul(x, wq, scale, bm=bm, bn=bn, bk=bk,
                      out_dtype=out_dtype, interpret=interpret)


def _fxp_matmul_diff_fwd(cfg, x, wq, scale):
    return _fxp_matmul_diff(cfg, x, wq, scale), (x, wq, scale)


def _fxp_matmul_diff_bwd(cfg, res, dy):
    bm, bn, bk, _, interpret = cfg
    x, wq, scale = res
    dx = matmul_dx(dy, wq, scale, bm=bm, bn=bn, bk=bk,
                   out_dtype=x.dtype, interpret=interpret)
    dw = matmul_dw(x, dy, bm=bm, bn=bn, bk=bk, interpret=interpret)
    dscale = (jnp.sum(dw * wq.astype(jnp.float32))
              .reshape(scale.shape).astype(scale.dtype))
    return dx, float0_like(wq), dscale


_fxp_matmul_diff.defvjp(_fxp_matmul_diff_fwd, _fxp_matmul_diff_bwd)


def fxp_matmul_vjp(x: Array, wq: Array, scale: Array, *, bm: int = 256,
                   bn: int = 256, bk: int = 512, out_dtype=None,
                   interpret: bool = False) -> Array:
    """Differentiable :func:`fxp_matmul`: same forward kernel, Pallas
    backward (``matmul_dx`` / ``matmul_dw``)."""
    return _fxp_matmul_diff((bm, bn, bk, out_dtype, interpret),
                            x, wq, jnp.asarray(scale, jnp.float32))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _int8_matmul_diff(cfg, xq, wq, sx, sw):
    bm, bn, bk, interpret = cfg
    return int8_matmul(xq, wq, sx, sw, bm=bm, bn=bn, bk=bk,
                       interpret=interpret)


def _int8_matmul_diff_fwd(cfg, xq, wq, sx, sw):
    return _int8_matmul_diff(cfg, xq, wq, sx, sw), (xq, wq, sx, sw)


def _int8_matmul_diff_bwd(cfg, res, dy):
    bm, bn, bk, interpret = cfg
    xq, wq, sx, sw = res
    # Recompute-based backward: both operands are int8 words (float0
    # cotangents), so the only gradients are the two scales. The raw int32
    # accumulator is regenerated by the forward kernel at unit scale.
    acc = int8_matmul(xq, wq, jnp.float32(1.0), jnp.float32(1.0),
                      bm=bm, bn=bn, bk=bk, interpret=interpret)
    g0 = jnp.sum(dy.astype(jnp.float32) * acc)
    dsx = (g0 * sw.astype(jnp.float32)).reshape(sx.shape).astype(sx.dtype)
    dsw = (g0 * sx.astype(jnp.float32)).reshape(sw.shape).astype(sw.dtype)
    return float0_like(xq), float0_like(wq), dsx, dsw


_int8_matmul_diff.defvjp(_int8_matmul_diff_fwd, _int8_matmul_diff_bwd)


def int8_matmul_vjp(xq: Array, wq: Array, sx: Array, sw: Array, *,
                    bm: int = 256, bn: int = 256, bk: int = 512,
                    interpret: bool = False) -> Array:
    """Differentiable :func:`int8_matmul` (scale cotangents only; the int8
    words are non-differentiable storage)."""
    return _int8_matmul_diff((bm, bn, bk, interpret), xq, wq,
                             jnp.asarray(sx, jnp.float32),
                             jnp.asarray(sw, jnp.float32))
