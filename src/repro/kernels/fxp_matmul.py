"""Pallas TPU kernel: fixed-point (int8-stored) matmul with fused dequant.

The AdaPT steady state keeps most layers at WL ≤ 8 (training starts at ⟨8,4⟩
and PushDown pushes down), so the hot matmul is
    y = x @ (wq · 2^-FL) (+ bias)
with wq int8. Doing dequant-then-matmul in XLA materializes a full f32/bf16
copy of the weights in HBM every step; this kernel streams int8 weight tiles
into VMEM (4× less HBM traffic than f32, 2× less than bf16) and dequantizes
in-register on the way into the MXU.

Block scheme: grid (M/bm, N/bn, K/bk), K innermost so the f32 accumulator
tile lives in a VMEM scratch across the K loop; MXU-aligned 128-multiples.

A full-integer variant (``int8_matmul``) takes int8 activations too and
accumulates in int32 — the v5e MXU's 2× int8 throughput path; used for
serving (W8A8) and benchmarked in §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import tpu_compiler_params

Array = jax.Array


def _fxp_matmul_kernel(x_ref, w_ref, scale_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)          # int8 -> f32 in-register
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        o_ref[...] = (acc_ref[...] * scale_ref[0, 0]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret",
                                             "out_dtype"))
def fxp_matmul(x: Array, wq: Array, scale: Array, *, bm: int = 256,
               bn: int = 256, bk: int = 512, out_dtype=None,
               interpret: bool = False) -> Array:
    """y = x @ (wq * scale).  x: (M,K) float; wq: (K,N) int8; scale: () f32."""
    M, K = x.shape
    K2, N = wq.shape
    assert K == K2, (x.shape, wq.shape)
    out_dtype = out_dtype or x.dtype
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    grid = (pl.cdiv(M, bm), pl.cdiv(N, bn), pl.cdiv(K, bk))
    kernel = functools.partial(_fxp_matmul_kernel, nk=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(x, wq, scale.reshape(1, 1).astype(jnp.float32))


def _int8_matmul_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        o_ref[...] = (acc_ref[...].astype(jnp.float32)
                      * s_ref[0, 0]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def int8_matmul(xq: Array, wq: Array, sx: Array, sw: Array, *, bm: int = 256,
                bn: int = 256, bk: int = 512, interpret: bool = False) -> Array:
    """W8A8 path: (xq @ wq) * (sx*sw); int32 MXU accumulation, f32 out."""
    M, K = xq.shape
    _, N = wq.shape
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    grid = (pl.cdiv(M, bm), pl.cdiv(N, bn), pl.cdiv(K, bk))
    kernel = functools.partial(_int8_matmul_kernel, nk=grid[2])
    s = (sx.astype(jnp.float32) * sw.astype(jnp.float32)).reshape(1, 1)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(xq, wq, s)
