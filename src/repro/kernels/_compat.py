"""Version-compat helpers shared by all Pallas TPU kernels.

JAX renamed ``pltpu.TPUCompilerParams`` → ``pltpu.CompilerParams`` across
releases; depending on the pinned jaxlib exactly one of the two exists.
Every kernel goes through :func:`tpu_compiler_params` so the spelling is
resolved in one place.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    """Build the TPU compiler-params object under either JAX spelling."""
    return _COMPILER_PARAMS_CLS(**kwargs)
