"""Pallas TPU kernel: fixed-point stochastic-rounding quantize (VPU, tiled).

The quantize→dequantize of every weight tensor runs once per optimizer step
(alg. 1 ln. 9–11) over *all* parameters — on an 8B model that is 8 G elements
of pure elementwise traffic, i.e. strictly HBM-bandwidth-bound. The kernel
tiles HBM→VMEM in (block_rows, 512)-float chunks and fuses scale/round/clip/
descale into one pass (vs 5+ XLA ops → one read+write of the tensor instead
of several).

⟨WL,FL⟩ arrive as an SMEM (1,2) int32 operand so one compiled kernel serves
every precision the controller chooses at runtime.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

LANE = 128


def _sr_quantize_kernel(wlfl_ref, x_ref, u_ref, o_ref):
    wl = wlfl_ref[0, 0].astype(jnp.float32)
    fl = wlfl_ref[0, 1].astype(jnp.float32)
    scale = jnp.exp2(fl)
    qmax = jnp.exp2(wl - 1.0) - 1.0
    x = x_ref[...].astype(jnp.float32)
    s = x * scale
    f = jnp.floor(s)
    q = f + (u_ref[...] < (s - f)).astype(jnp.float32)
    q = jnp.clip(q, -qmax - 1.0, qmax)
    o_ref[...] = (q / scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def sr_quantize(x: Array, u: Array, wl: Array, fl: Array, *,
                block_rows: int = 256, interpret: bool = False) -> Array:
    """Quantize ``x`` onto the ⟨wl,fl⟩ grid with stochastic rounding.

    x: any shape/float dtype; u: U[0,1) f32 of same shape; wl/fl: int32 scalars.
    """
    shape, dtype = x.shape, x.dtype
    n = x.size
    cols = LANE * 4                       # 512-float lanes per row
    rows = pl.cdiv(n, cols)
    pad = rows * cols - n
    x2 = jnp.pad(x.reshape(-1).astype(jnp.float32), (0, pad)).reshape(rows, cols)
    u2 = jnp.pad(u.reshape(-1).astype(jnp.float32), (0, pad)).reshape(rows, cols)
    wlfl = jnp.stack([wl, fl]).astype(jnp.int32).reshape(1, 2)

    grid = (pl.cdiv(rows, block_rows),)
    out = pl.pallas_call(
        _sr_quantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),             # wl/fl scalars
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=interpret,
    )(wlfl, x2, u2)
    return out.reshape(-1)[:n].reshape(shape).astype(dtype)
