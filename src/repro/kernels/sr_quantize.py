"""Pallas TPU kernels: fixed-point stochastic-rounding quantize (VPU, tiled).

The quantize→dequantize of every weight tensor runs once per optimizer step
(alg. 1 ln. 9–11) over *all* parameters — on an 8B model that is 8 G elements
of pure elementwise traffic, i.e. strictly HBM-bandwidth-bound. The kernels
tile HBM→VMEM in (block_rows, 512)-float chunks and fuse scale/round/clip/
descale into one pass (vs 5+ XLA ops → one read+write of the tensor instead
of several).

Two families:

* ``sr_quantize`` — takes a precomputed U[0,1) noise tensor. Three
  param-sized HBM transfers per tensor (x in, u in, q out), *plus* the
  earlier write of u when jax.random generated it: ~4 total.
* ``sr_quantize_fused`` / ``sr_quantize_fused_int8`` — draws the noise
  *inside* the kernel, so the U[0,1) tensor never exists in HBM: exactly
  two param-sized transfers per tensor (x in, q out). On TPU the noise
  comes from the hardware PRNG (``pltpu.prng_seed`` seeded per ⟨seed,
  block⟩ + ``pltpu.prng_random_bits``); under ``interpret=True`` (CPU/CI,
  where those primitives have no lowering) an in-kernel counter-based
  hash (splitmix/murmur3-finalizer over the global element index) supplies
  the bits instead. Both streams are deterministic per seed; they are
  *different* streams, so cross-backend runs agree in distribution (and on
  every grid/clip property) but not bit-for-bit.
* ``sr_quantize_fused_stacked`` / ``sr_quantize_fused_stacked_int8`` —
  the same 2-transfer contract for per-layer-stacked leaves: ⟨WL,FL⟩ is an
  (L,)-vector staged through SMEM, the grid grows a leading per-layer dim,
  and layer l quantizes with its own scale/clip in the SAME launch (vs the
  old L-pass XLA fallback). The portable noise stream indexes the padded
  (L·rows, 512) stack flat, so L=1 is bit-identical to the unstacked
  kernel and the stream is independent of ``block_rows``.

⟨WL,FL⟩ (and the seed) arrive as an SMEM int32 operand so one compiled
kernel serves every precision the controller chooses at runtime. The
portable counter-hash stream is a *contract* — ``kernels/ref.py``
regenerates it bit-for-bit (``ref_fused_noise``) so the differential
harness (tests/test_quantize_differential.py) demands word equality, and
``fold_shard_seed`` defines the per-shard seed derivation the shard_map
wrapper in ``kernels/ops.py`` uses for sharded leaves.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

LANE = 128


def _pow2i(e: Array) -> Array:
    """Exact 2^e (f32) for int32 e, built from the exponent bits (clamped
    to the normal range [-126, 127]). XLA CPU lowers ``exp2`` to
    ``exp(e·ln2)``, which is off by an ulp for |e| ≳ 10 — enough to knock
    the ⟨WL,FL⟩ grid off its exact powers of two; the quantize kernels must
    never be. In-kernel mirror of ``core.fixed_point.pow2i`` (the kernels
    stay import-free of core)."""
    e = jnp.clip(e.astype(jnp.int32), -126, 127)
    return jax.lax.bitcast_convert_type((e + 127) << 23, jnp.float32)


def _sr_quantize_kernel(wlfl_ref, x_ref, u_ref, o_ref):
    scale = _pow2i(wlfl_ref[0, 1])
    qmax = _pow2i(wlfl_ref[0, 0] - 1) - 1.0
    x = x_ref[...].astype(jnp.float32)
    s = x * scale
    f = jnp.floor(s)
    q = f + (u_ref[...] < (s - f)).astype(jnp.float32)
    q = jnp.clip(q, -qmax - 1.0, qmax)
    o_ref[...] = (q / scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def sr_quantize(x: Array, u: Array, wl: Array, fl: Array, *,
                block_rows: int = 256, interpret: bool = False) -> Array:
    """Quantize ``x`` onto the ⟨wl,fl⟩ grid with stochastic rounding.

    x: any shape/float dtype; u: U[0,1) f32 of same shape; wl/fl: int32 scalars.
    """
    shape, dtype = x.shape, x.dtype
    n = x.size
    cols = LANE * 4                       # 512-float lanes per row
    rows = pl.cdiv(n, cols)
    pad = rows * cols - n
    x2 = jnp.pad(x.reshape(-1).astype(jnp.float32), (0, pad)).reshape(rows, cols)
    u2 = jnp.pad(u.reshape(-1).astype(jnp.float32), (0, pad)).reshape(rows, cols)
    wlfl = jnp.stack([wl, fl]).astype(jnp.int32).reshape(1, 2)

    grid = (pl.cdiv(rows, block_rows),)
    out = pl.pallas_call(
        _sr_quantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),             # wl/fl scalars
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=interpret,
    )(wlfl, x2, u2)
    return out.reshape(-1)[:n].reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# Fused-PRNG variants: noise is drawn inside the kernel, never touching HBM.


def uniform_from_index(seed: Array, idx: Array) -> Array:
    """Portable U[0,1) from a uint32 element index: murmur3-finalizer of
    the index mixed with the seed (golden-ratio stride). THE bit-pinned
    portable stream (``ref.ref_fused_noise`` regenerates it; the golden
    file trips on drift) — every kernel that draws noise for element
    ``idx`` of a tensor must come through here so streams agree across
    kernels that tile the same tensor differently (e.g. the quantize
    prologue of ``fxp_matmul.fxp_qmatmul`` vs its dx recompute)."""
    h = idx.astype(jnp.uint32) + seed.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)
    h ^= h >> 16
    h = h * jnp.uint32(0x7FEB352D)
    h ^= h >> 15
    h = h * jnp.uint32(0x846CA68B)
    h ^= h >> 16
    return (h >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def _hash_uniform(seed: Array, shape, row0: Array, cols: int) -> Array:
    """Portable in-kernel U[0,1) over a (rows, cols) padded layout: the
    global element index (row0 + r)·cols + c fed to
    :func:`uniform_from_index`. Runs anywhere — it is the noise source
    whenever the hardware PRNG primitives are unavailable (interpret mode /
    CPU CI). Index arithmetic wraps mod 2^32, so streams repeat only
    beyond 4G-element tensors."""
    r = jax.lax.broadcasted_iota(jnp.uint32, shape, 0)
    c = jax.lax.broadcasted_iota(jnp.uint32, shape, 1)
    idx = (row0.astype(jnp.uint32) + r) * jnp.uint32(cols) + c
    return uniform_from_index(seed, idx)


def _hw_uniform(seed: Array, shape, block_ids) -> Array:
    # Distinct hardware stream per ⟨seed, block ids⟩; reseeding per block
    # keeps the stream independent of the grid schedule.
    pltpu.prng_seed(seed, *block_ids)
    bits = pltpu.prng_random_bits(shape)
    u32 = pltpu.bitcast(bits, jnp.uint32)
    return (u32 >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def _inkernel_uniform(seed: Array, shape, block_rows: int, cols: int,
                      hw_prng: bool) -> Array:
    if hw_prng:
        return _hw_uniform(seed, shape, (pl.program_id(0),))
    row0 = pl.program_id(0) * block_rows
    return _hash_uniform(seed, shape, row0, cols)


def fold_shard_seed(seed: Array, idx: Array) -> Array:
    """Per-shard seed for the shard_map-wrapped fused quantize: splitmix-
    style fold of the linear shard index into the base seed (int32 in/out,
    bit pattern of the mixed uint32). The sharded stream is thus a pure
    function of ⟨seed, mesh layout⟩ — ``ref.ref_fold_shard_seed`` mirrors
    this exactly, and the golden-stream test pins it against drift."""
    s = (jnp.asarray(seed, jnp.int32).astype(jnp.uint32)
         + jnp.asarray(idx, jnp.uint32) * jnp.uint32(0x9E3779B9))
    s = s ^ (s >> 16)
    s = s * jnp.uint32(0x7FEB352D)
    s = s ^ (s >> 15)
    return jax.lax.bitcast_convert_type(s, jnp.int32)


def _sr_fused_kernel(ctl_ref, x_ref, o_ref, *, block_rows: int, cols: int,
                     hw_prng: bool):
    seed = ctl_ref[0, 2]
    scale = _pow2i(ctl_ref[0, 1])
    qmax = _pow2i(ctl_ref[0, 0] - 1) - 1.0
    x = x_ref[...].astype(jnp.float32)
    u = _inkernel_uniform(seed, x.shape, block_rows, cols, hw_prng)
    s = x * scale
    f = jnp.floor(s)
    q = f + (u < (s - f)).astype(jnp.float32)
    q = jnp.clip(q, -qmax - 1.0, qmax)
    o_ref[...] = (q / scale).astype(o_ref.dtype)


def _sr_fused_int8_kernel(ctl_ref, x_ref, o_ref, *, block_rows: int,
                          cols: int, hw_prng: bool):
    # Native-int8 storage path: the word is clipped to int8 range (WL≤8 by
    # construction of the mode), matching controller.quantize_params' int8
    # branch; dequant (· 2^-FL) happens at the consumer.
    seed = ctl_ref[0, 1]
    scale = _pow2i(ctl_ref[0, 0])
    x = x_ref[...].astype(jnp.float32)
    u = _inkernel_uniform(seed, x.shape, block_rows, cols, hw_prng)
    s = x * scale
    f = jnp.floor(s)
    q = f + (u < (s - f)).astype(jnp.float32)
    o_ref[...] = jnp.clip(q, -128.0, 127.0).astype(jnp.int8)


def _fused_call(kernel, ctl: Array, x: Array, out_dtype, *, block_rows: int,
                interpret: bool, hw_prng: bool):
    n = x.size
    cols = LANE * 4
    rows = pl.cdiv(n, cols)
    pad = rows * cols - n
    x2 = jnp.pad(x.reshape(-1).astype(jnp.float32), (0, pad)).reshape(rows, cols)
    grid = (pl.cdiv(rows, block_rows),)
    body = functools.partial(kernel, block_rows=block_rows, cols=cols,
                             hw_prng=hw_prng)
    out = pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),     # ⟨wl,fl,seed⟩ / ⟨fl,seed⟩
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), out_dtype),
        interpret=interpret,
    )(ctl, x2)
    return out.reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret",
                                             "hw_prng"))
def sr_quantize_fused(x: Array, seed: Array, wl: Array, fl: Array, *,
                      block_rows: int = 256, interpret: bool = False,
                      hw_prng: bool = False) -> Array:
    """SR quantize with in-kernel noise: 2 param-sized HBM transfers total.

    x: any shape/float dtype; seed: int32 scalar; wl/fl: int32 scalars.
    ``hw_prng=True`` uses the TPU hardware PRNG (compiled TPU runs only);
    otherwise the portable counter-hash stream is used. Deterministic per
    ⟨seed, block_rows⟩ either way.
    """
    shape, dtype = x.shape, x.dtype
    ctl = jnp.stack([jnp.asarray(wl), jnp.asarray(fl),
                     jnp.asarray(seed)]).astype(jnp.int32).reshape(1, 3)
    out = _fused_call(_sr_fused_kernel, ctl, x, jnp.float32,
                      block_rows=block_rows, interpret=interpret,
                      hw_prng=hw_prng)
    return out.reshape(shape).astype(dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret",
                                             "hw_prng"))
def sr_quantize_fused_int8(x: Array, seed: Array, fl: Array, *,
                           block_rows: int = 256, interpret: bool = False,
                           hw_prng: bool = False) -> Array:
    """Int8-word flavor for the native_int8/packed path: returns
    round-stochastic(x·2^FL) clipped to int8, as an int8 tensor. Dequant is
    ``q8 * 2^-FL`` at the consumer (after the FSDP gather)."""
    shape = x.shape
    ctl = jnp.stack([jnp.asarray(fl),
                     jnp.asarray(seed)]).astype(jnp.int32).reshape(1, 2)
    out = _fused_call(_sr_fused_int8_kernel, ctl, x, jnp.int8,
                      block_rows=block_rows, interpret=interpret,
                      hw_prng=hw_prng)
    return out.reshape(shape)


# ---------------------------------------------------------------------------
# Per-layer-stacked variants: an (L,)-vector ⟨WL,FL⟩ operand in SMEM plus a
# leading per-layer grid dimension — one launch quantizes a whole
# transformer stack, each layer on its own grid.


def _stacked_uniform(seed: Array, shape, l, blk, block_rows: int, cols: int,
                     rows: int, hw_prng: bool) -> Array:
    if hw_prng:
        return _hw_uniform(seed, shape, (l, blk))
    # Flat index over the padded (L·rows, cols) stack: layer l's stream
    # starts at row l·rows, so L=1 degenerates to the unstacked stream and
    # the bits never depend on block_rows.
    row0 = l * rows + blk * block_rows
    return _hash_uniform(seed, shape, row0, cols)


def _sr_fused_stacked_kernel(seed_ref, wlfl_ref, x_ref, o_ref, *,
                             block_rows: int, cols: int, rows: int,
                             hw_prng: bool):
    l = pl.program_id(0)
    seed = seed_ref[0, 0]
    scale = _pow2i(wlfl_ref[l, 1])
    qmax = _pow2i(wlfl_ref[l, 0] - 1) - 1.0
    x = x_ref[0].astype(jnp.float32)
    u = _stacked_uniform(seed, x.shape, l, pl.program_id(1), block_rows,
                         cols, rows, hw_prng)
    s = x * scale
    f = jnp.floor(s)
    q = f + (u < (s - f)).astype(jnp.float32)
    q = jnp.clip(q, -qmax - 1.0, qmax)
    o_ref[0] = (q / scale).astype(o_ref.dtype)


def _sr_fused_stacked_int8_kernel(seed_ref, fl_ref, x_ref, o_ref, *,
                                  block_rows: int, cols: int, rows: int,
                                  hw_prng: bool):
    l = pl.program_id(0)
    seed = seed_ref[0, 0]
    scale = _pow2i(fl_ref[l, 0])
    x = x_ref[0].astype(jnp.float32)
    u = _stacked_uniform(seed, x.shape, l, pl.program_id(1), block_rows,
                         cols, rows, hw_prng)
    s = x * scale
    f = jnp.floor(s)
    q = f + (u < (s - f)).astype(jnp.float32)
    o_ref[0] = jnp.clip(q, -128.0, 127.0).astype(jnp.int8)


def _stacked_call(kernel, ctl: Array, x: Array, out_dtype, *,
                  block_rows: int, interpret: bool, hw_prng: bool):
    L = x.shape[0]
    n = x.size // L
    cols = LANE * 4
    rows = pl.cdiv(n, cols)
    pad = rows * cols - n
    x2 = jnp.pad(x.reshape(L, -1).astype(jnp.float32),
                 ((0, 0), (0, pad))).reshape(L, rows, cols)
    seed2 = ctl[0]
    grid = (L, pl.cdiv(rows, block_rows))
    body = functools.partial(kernel, block_rows=block_rows, cols=cols,
                             rows=rows, hw_prng=hw_prng)
    out = pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),     # seed (1, 1)
            pl.BlockSpec(memory_space=pltpu.SMEM),     # per-layer ⟨WL,FL⟩/FL
            pl.BlockSpec((1, block_rows, cols), lambda l, i: (l, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_rows, cols), lambda l, i: (l, i, 0)),
        out_shape=jax.ShapeDtypeStruct((L, rows, cols), out_dtype),
        interpret=interpret,
    )(seed2, ctl[1], x2)
    return out.reshape(L, rows * cols)[:, :n]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret",
                                             "hw_prng"))
def sr_quantize_fused_stacked(x: Array, seed: Array, wl: Array, fl: Array, *,
                              block_rows: int = 256, interpret: bool = False,
                              hw_prng: bool = False) -> Array:
    """Per-layer-stacked SR quantize with in-kernel noise: x (L, ...) is
    quantized so slice l sits on the ⟨wl[l], fl[l]⟩ grid, in ONE kernel
    launch (grid (L, row-blocks), precision vector in SMEM). Same 2-HBM-
    transfer contract as :func:`sr_quantize_fused`; bit-identical to it for
    L=1 under the portable stream."""
    shape, dtype = x.shape, x.dtype
    seed2 = jnp.asarray(seed, jnp.int32).reshape(1, 1)
    wlfl = jnp.stack([jnp.asarray(wl), jnp.asarray(fl)],
                     axis=1).astype(jnp.int32)
    out = _stacked_call(_sr_fused_stacked_kernel, (seed2, wlfl), x,
                        jnp.float32, block_rows=block_rows,
                        interpret=interpret, hw_prng=hw_prng)
    return out.reshape(shape).astype(dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret",
                                             "hw_prng"))
def sr_quantize_fused_stacked_int8(x: Array, seed: Array, fl: Array, *,
                                   block_rows: int = 256,
                                   interpret: bool = False,
                                   hw_prng: bool = False) -> Array:
    """Int8-word flavor of :func:`sr_quantize_fused_stacked`: layer l's
    words are round-stochastic(x[l]·2^fl[l]) clipped to int8. Dequant is
    ``q8[l] * 2^-fl[l]`` at the consumer."""
    shape = x.shape
    seed2 = jnp.asarray(seed, jnp.int32).reshape(1, 1)
    fl2 = jnp.asarray(fl, jnp.int32).reshape(-1, 1)
    out = _stacked_call(_sr_fused_stacked_int8_kernel, (seed2, fl2), x,
                        jnp.int8, block_rows=block_rows, interpret=interpret,
                        hw_prng=hw_prng)
    return out.reshape(shape)
