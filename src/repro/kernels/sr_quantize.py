"""Pallas TPU kernels: fixed-point stochastic-rounding quantize (VPU, tiled).

The quantize→dequantize of every weight tensor runs once per optimizer step
(alg. 1 ln. 9–11) over *all* parameters — on an 8B model that is 8 G elements
of pure elementwise traffic, i.e. strictly HBM-bandwidth-bound. The kernels
tile HBM→VMEM in (block_rows, 512)-float chunks and fuse scale/round/clip/
descale into one pass (vs 5+ XLA ops → one read+write of the tensor instead
of several).

Two families:

* ``sr_quantize`` — takes a precomputed U[0,1) noise tensor. Three
  param-sized HBM transfers per tensor (x in, u in, q out), *plus* the
  earlier write of u when jax.random generated it: ~4 total.
* ``sr_quantize_fused`` / ``sr_quantize_fused_int8`` — draws the noise
  *inside* the kernel, so the U[0,1) tensor never exists in HBM: exactly
  two param-sized transfers per tensor (x in, q out). On TPU the noise
  comes from the hardware PRNG (``pltpu.prng_seed`` seeded per ⟨seed,
  block⟩ + ``pltpu.prng_random_bits``); under ``interpret=True`` (CPU/CI,
  where those primitives have no lowering) an in-kernel counter-based
  hash (splitmix/murmur3-finalizer over the global element index) supplies
  the bits instead. Both streams are deterministic per seed; they are
  *different* streams, so cross-backend runs agree in distribution (and on
  every grid/clip property) but not bit-for-bit.

⟨WL,FL⟩ (and the seed) arrive as an SMEM int32 operand so one compiled
kernel serves every precision the controller chooses at runtime.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

LANE = 128


def _sr_quantize_kernel(wlfl_ref, x_ref, u_ref, o_ref):
    wl = wlfl_ref[0, 0].astype(jnp.float32)
    fl = wlfl_ref[0, 1].astype(jnp.float32)
    scale = jnp.exp2(fl)
    qmax = jnp.exp2(wl - 1.0) - 1.0
    x = x_ref[...].astype(jnp.float32)
    s = x * scale
    f = jnp.floor(s)
    q = f + (u_ref[...] < (s - f)).astype(jnp.float32)
    q = jnp.clip(q, -qmax - 1.0, qmax)
    o_ref[...] = (q / scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def sr_quantize(x: Array, u: Array, wl: Array, fl: Array, *,
                block_rows: int = 256, interpret: bool = False) -> Array:
    """Quantize ``x`` onto the ⟨wl,fl⟩ grid with stochastic rounding.

    x: any shape/float dtype; u: U[0,1) f32 of same shape; wl/fl: int32 scalars.
    """
    shape, dtype = x.shape, x.dtype
    n = x.size
    cols = LANE * 4                       # 512-float lanes per row
    rows = pl.cdiv(n, cols)
    pad = rows * cols - n
    x2 = jnp.pad(x.reshape(-1).astype(jnp.float32), (0, pad)).reshape(rows, cols)
    u2 = jnp.pad(u.reshape(-1).astype(jnp.float32), (0, pad)).reshape(rows, cols)
    wlfl = jnp.stack([wl, fl]).astype(jnp.int32).reshape(1, 2)

    grid = (pl.cdiv(rows, block_rows),)
    out = pl.pallas_call(
        _sr_quantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),             # wl/fl scalars
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=interpret,
    )(wlfl, x2, u2)
    return out.reshape(-1)[:n].reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# Fused-PRNG variants: noise is drawn inside the kernel, never touching HBM.


def _hash_uniform(seed: Array, shape, row0: Array, cols: int) -> Array:
    """Portable in-kernel U[0,1): murmur3-finalizer of the global element
    index mixed with the seed (golden-ratio stride). Runs anywhere — it is
    the noise source whenever the hardware PRNG primitives are unavailable
    (interpret mode / CPU CI). Index arithmetic wraps mod 2^32, so streams
    repeat only beyond 4G-element tensors."""
    r = jax.lax.broadcasted_iota(jnp.uint32, shape, 0)
    c = jax.lax.broadcasted_iota(jnp.uint32, shape, 1)
    h = (row0.astype(jnp.uint32) + r) * jnp.uint32(cols) + c
    h = h + seed.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)
    h ^= h >> 16
    h = h * jnp.uint32(0x7FEB352D)
    h ^= h >> 15
    h = h * jnp.uint32(0x846CA68B)
    h ^= h >> 16
    return (h >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def _inkernel_uniform(seed: Array, shape, block_rows: int, cols: int,
                      hw_prng: bool) -> Array:
    if hw_prng:
        # Distinct hardware stream per ⟨seed, block⟩; reseeding per block
        # keeps the stream independent of the grid schedule.
        pltpu.prng_seed(seed, pl.program_id(0))
        bits = pltpu.prng_random_bits(shape)
        u32 = pltpu.bitcast(bits, jnp.uint32)
        return (u32 >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))
    row0 = pl.program_id(0) * block_rows
    return _hash_uniform(seed, shape, row0, cols)


def _sr_fused_kernel(ctl_ref, x_ref, o_ref, *, block_rows: int, cols: int,
                     hw_prng: bool):
    wl = ctl_ref[0, 0].astype(jnp.float32)
    fl = ctl_ref[0, 1].astype(jnp.float32)
    seed = ctl_ref[0, 2]
    scale = jnp.exp2(fl)
    qmax = jnp.exp2(wl - 1.0) - 1.0
    x = x_ref[...].astype(jnp.float32)
    u = _inkernel_uniform(seed, x.shape, block_rows, cols, hw_prng)
    s = x * scale
    f = jnp.floor(s)
    q = f + (u < (s - f)).astype(jnp.float32)
    q = jnp.clip(q, -qmax - 1.0, qmax)
    o_ref[...] = (q / scale).astype(o_ref.dtype)


def _sr_fused_int8_kernel(ctl_ref, x_ref, o_ref, *, block_rows: int,
                          cols: int, hw_prng: bool):
    # Native-int8 storage path: the word is clipped to int8 range (WL≤8 by
    # construction of the mode), matching controller.quantize_params' int8
    # branch; dequant (· 2^-FL) happens at the consumer.
    fl = ctl_ref[0, 0].astype(jnp.float32)
    seed = ctl_ref[0, 1]
    scale = jnp.exp2(fl)
    x = x_ref[...].astype(jnp.float32)
    u = _inkernel_uniform(seed, x.shape, block_rows, cols, hw_prng)
    s = x * scale
    f = jnp.floor(s)
    q = f + (u < (s - f)).astype(jnp.float32)
    o_ref[...] = jnp.clip(q, -128.0, 127.0).astype(jnp.int8)


def _fused_call(kernel, ctl: Array, x: Array, out_dtype, *, block_rows: int,
                interpret: bool, hw_prng: bool):
    n = x.size
    cols = LANE * 4
    rows = pl.cdiv(n, cols)
    pad = rows * cols - n
    x2 = jnp.pad(x.reshape(-1).astype(jnp.float32), (0, pad)).reshape(rows, cols)
    grid = (pl.cdiv(rows, block_rows),)
    body = functools.partial(kernel, block_rows=block_rows, cols=cols,
                             hw_prng=hw_prng)
    out = pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),     # ⟨wl,fl,seed⟩ / ⟨fl,seed⟩
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), out_dtype),
        interpret=interpret,
    )(ctl, x2)
    return out.reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret",
                                             "hw_prng"))
def sr_quantize_fused(x: Array, seed: Array, wl: Array, fl: Array, *,
                      block_rows: int = 256, interpret: bool = False,
                      hw_prng: bool = False) -> Array:
    """SR quantize with in-kernel noise: 2 param-sized HBM transfers total.

    x: any shape/float dtype; seed: int32 scalar; wl/fl: int32 scalars.
    ``hw_prng=True`` uses the TPU hardware PRNG (compiled TPU runs only);
    otherwise the portable counter-hash stream is used. Deterministic per
    ⟨seed, block_rows⟩ either way.
    """
    shape, dtype = x.shape, x.dtype
    ctl = jnp.stack([jnp.asarray(wl), jnp.asarray(fl),
                     jnp.asarray(seed)]).astype(jnp.int32).reshape(1, 3)
    out = _fused_call(_sr_fused_kernel, ctl, x, jnp.float32,
                      block_rows=block_rows, interpret=interpret,
                      hw_prng=hw_prng)
    return out.reshape(shape).astype(dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret",
                                             "hw_prng"))
def sr_quantize_fused_int8(x: Array, seed: Array, fl: Array, *,
                           block_rows: int = 256, interpret: bool = False,
                           hw_prng: bool = False) -> Array:
    """Int8-word flavor for the native_int8/packed path: returns
    round-stochastic(x·2^FL) clipped to int8, as an int8 tensor. Dequant is
    ``q8 * 2^-FL`` at the consumer (after the FSDP gather)."""
    shape = x.shape
    ctl = jnp.stack([jnp.asarray(fl),
                     jnp.asarray(seed)]).astype(jnp.int32).reshape(1, 2)
    out = _fused_call(_sr_fused_int8_kernel, ctl, x, jnp.int8,
                      block_rows=block_rows, interpret=interpret,
                      hw_prng=hw_prng)
    return out.reshape(shape)
