"""Jit'd dispatch wrappers over the Pallas kernels.

Each op picks the Pallas path on TPU (or when forced) and falls back to the
pure-jnp oracle otherwise; `interpret=True` is used automatically on CPU so
the kernels stay exercised (and tested) in this container.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import edf_ladder as _el
from repro.kernels import flash_attention as _fa
from repro.kernels import fxp_matmul as _fm
from repro.kernels import kl_hist as _kh
from repro.kernels import ref
from repro.kernels import sr_quantize as _sq

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def sr_quantize(x: Array, u: Array, wl, fl, *, use_pallas: bool = False) -> Array:
    if use_pallas:
        return _sq.sr_quantize(x, u, jnp.asarray(wl, jnp.int32),
                               jnp.asarray(fl, jnp.int32),
                               interpret=not _on_tpu())
    return ref.ref_sr_quantize(x, u, wl, fl)


def sr_quantize_fused(x: Array, seed, wl, fl, *,
                      use_pallas: bool = False) -> Array:
    """SR quantize with in-kernel noise (no U[0,1) tensor in HBM). The
    hardware PRNG is used on compiled TPU runs; interpret mode (CPU CI) uses
    the kernel's portable counter-hash stream; the non-Pallas fallback draws
    an explicit jax.random stream. All are deterministic per seed."""
    if use_pallas:
        on_tpu = _on_tpu()
        return _sq.sr_quantize_fused(x, jnp.asarray(seed, jnp.int32),
                                     jnp.asarray(wl, jnp.int32),
                                     jnp.asarray(fl, jnp.int32),
                                     interpret=not on_tpu, hw_prng=on_tpu)
    return ref.ref_sr_quantize_fused(x, seed, wl, fl)


def sr_quantize_fused_int8(x: Array, seed, fl, *,
                           use_pallas: bool = False) -> Array:
    """Int8-word flavor of :func:`sr_quantize_fused` for the native_int8 /
    packed path: returns the int8 fixed-point words (dequant = q8·2^-FL)."""
    if use_pallas:
        on_tpu = _on_tpu()
        return _sq.sr_quantize_fused_int8(x, jnp.asarray(seed, jnp.int32),
                                          jnp.asarray(fl, jnp.int32),
                                          interpret=not on_tpu,
                                          hw_prng=on_tpu)
    return ref.ref_sr_quantize_fused_int8(x, seed, fl)


def edf_ladder_hists(w: Array, fls: Array, r, *, wl_ladder: tuple,
                     r_upr: int, use_pallas: bool = False) -> Array:
    """(1+T, r_upr) master + per-WL-candidate histograms in one data pass."""
    if use_pallas:
        return _el.edf_ladder_hists(w, fls, jnp.asarray(r, jnp.int32),
                                    wl_ladder=wl_ladder, r_upr=r_upr,
                                    interpret=not _on_tpu())
    return ref.ref_edf_ladder_hists(w, fls, jnp.asarray(r, jnp.int32),
                                    wl_ladder=wl_ladder, r_upr=r_upr)


def fxp_matmul(x: Array, wq: Array, scale: Array, *, use_pallas: bool = False,
               bias: Array | None = None) -> Array:
    if use_pallas:
        out = _fm.fxp_matmul(x, wq, scale, interpret=not _on_tpu())
        if bias is not None:
            out = out + bias
        return out
    return ref.ref_fxp_matmul(x, wq, scale, bias)


def int8_matmul(xq: Array, wq: Array, sx: Array, sw: Array, *,
                use_pallas: bool = False) -> Array:
    if use_pallas:
        return _fm.int8_matmul(xq, wq, sx, sw, interpret=not _on_tpu())
    return ref.ref_int8_matmul(xq, wq, sx, sw)


def kl_hist(w: Array, q: Array, num_bins: int = 256, *,
            use_pallas: bool = False) -> Array:
    if use_pallas:
        return _kh.kl_hist(w, q, num_bins=num_bins, interpret=not _on_tpu())
    return ref.ref_kl_hist(w, q, num_bins)


def attention(q: Array, k: Array, v: Array, *, causal: bool = True,
              window: int = 0, softcap: float = 0.0,
              scale: float | None = None, use_pallas: bool = False,
              bq: int = 512, bk: int = 512) -> Array:
    if use_pallas:
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   softcap=softcap, scale=scale, bq=bq, bk=bk,
                                   interpret=not _on_tpu())
    return ref.ref_attention(q, k, v, causal=causal, window=window,
                             softcap=softcap, scale=scale)
