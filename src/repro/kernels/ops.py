"""Jit'd dispatch wrappers over the Pallas kernels.

Each op picks the Pallas path on TPU (or when forced) and falls back to the
pure-jnp oracle otherwise; `interpret=True` is used automatically on CPU so
the kernels stay exercised (and tested) in this container.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import sharding as shd
from repro.kernels import edf_ladder as _el
from repro.kernels import flash_attention as _fa
from repro.kernels import fxp_matmul as _fm
from repro.kernels import kl_hist as _kh
from repro.kernels import ref
from repro.kernels import sr_quantize as _sq

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def sr_quantize(x: Array, u: Array, wl, fl, *, use_pallas: bool = False) -> Array:
    if use_pallas:
        return _sq.sr_quantize(x, u, jnp.asarray(wl, jnp.int32),
                               jnp.asarray(fl, jnp.int32),
                               interpret=not _on_tpu())
    return ref.ref_sr_quantize(x, u, wl, fl)


def _dim_spec(axes: tuple):
    return None if not axes else (axes[0] if len(axes) == 1 else axes)


def _fused_sharded(x: Array, seed: Array, extras, extra_lead, call,
                   sharding) -> Array:
    """shard_map-wrap ``call(x_loc, seed_loc, *extra_locs)`` over the leaf's
    NamedSharding. pallas_call has no SPMD partitioning rule — under plain
    GSPMD the kernel would be REPLICATED (all-gathering the f32 master), so
    the wrapper goes manual over every mesh axis the spec names and derives
    a per-shard seed by folding the linear shard index
    (``sr_quantize.fold_shard_seed``): the global stream is a pure function
    of ⟨seed, mesh layout⟩, bit-reproducible on any host
    (``ref.ref_sr_quantize_fused_sharded_words``). ``extra_lead[i]`` marks
    extras[i] as an (L,)-vector following the leaf's leading dim (stacked
    ⟨WL,FL⟩); other extras are replicated scalars. Callers must have
    checked even divisibility (``sharding.shard_grid``)."""
    mesh = sharding.mesh
    per_dim = shd.spec_dim_axes(sharding.spec, x.ndim)
    folded = tuple(a for axes in per_dim for a in axes)
    if not folded:                    # fully replicated: plain kernel call
        return call(x, seed, *extras)
    xspec = P(*[_dim_spec(a) for a in per_dim])
    lead = P(_dim_spec(per_dim[0]))

    def body(x_loc, seed_, *extra_locs):
        # Fold only the axes the spec names: devices along the remaining
        # (replication) axes hold identical blocks and must compute
        # identical words.
        idx = jnp.int32(0)
        for a in folded:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        return call(x_loc, _sq.fold_shard_seed(seed_, idx), *extra_locs)

    in_specs = (xspec, P()) + tuple(lead if is_lead else P()
                                    for is_lead in extra_lead)
    # Manual over the WHOLE mesh (partial-manual shard_map only lowers
    # under jit on the pinned jaxlib; full-manual also runs eagerly) —
    # unnamed axes simply see replicated blocks.
    return shd.shard_map(body, mesh, axis_names=set(mesh.axis_names),
                         in_specs=in_specs, out_specs=xspec)(x, seed, *extras)


def sr_quantize_fused(x: Array, seed, wl, fl, *, use_pallas: bool = False,
                      sharding=None) -> Array:
    """SR quantize with in-kernel noise (no U[0,1) tensor in HBM), serving
    all three dispatch regimes of the 2-transfer path:

    * scalar ⟨wl, fl⟩           → ``sr_quantize_fused`` directly;
    * (L,)-vector ⟨wl, fl⟩      → the per-layer-stacked kernel (leading
      grid dim + SMEM precision vector, one launch for the whole stack);
    * ``sharding`` a NamedSharding with mesh axes in its spec → the kernel
      (stacked or not) wrapped in ``sharding.shard_map`` with per-shard
      folded seeds, so FSDP/TP leaves keep the 2-transfer path with zero
      collectives.

    The hardware PRNG is used on compiled TPU runs; interpret mode (CPU
    CI) uses the portable counter-hash stream; the non-Pallas fallback
    draws an explicit jax.random stream. All are deterministic per seed."""
    seed = jnp.asarray(seed, jnp.int32)
    wl = jnp.asarray(wl, jnp.int32)
    fl = jnp.asarray(fl, jnp.int32)
    stacked = bool(wl.ndim)
    if use_pallas:
        on_tpu = _on_tpu()

        def call(xv, sv, wlv, flv):
            if stacked:
                return _sq.sr_quantize_fused_stacked(
                    xv, sv, wlv, flv, interpret=not on_tpu, hw_prng=on_tpu)
            return _sq.sr_quantize_fused(xv, sv, wlv, flv,
                                         interpret=not on_tpu,
                                         hw_prng=on_tpu)

        if sharding is not None:
            return _fused_sharded(x, seed, (wl, fl), (stacked, stacked),
                                  call, sharding)
        return call(x, seed, wl, fl)
    if sharding is not None:
        # The jax.random fallback can honor neither the per-shard seed
        # contract nor the no-collective guarantee — refuse loudly rather
        # than silently re-introducing the f32 all-gather.
        raise ValueError("sr_quantize_fused: sharding= requires "
                         "use_pallas=True (the XLA fallback would gather "
                         "the master; use the noise+constraint path "
                         "instead)")
    if stacked:
        b = (wl.shape[0],) + (1,) * (x.ndim - 1)
        return ref.ref_sr_quantize_fused(x, seed, wl.reshape(b),
                                         fl.reshape(b))
    return ref.ref_sr_quantize_fused(x, seed, wl, fl)


def sr_quantize_fused_int8(x: Array, seed, fl, *, use_pallas: bool = False,
                           sharding=None) -> Array:
    """Int8-word flavor of :func:`sr_quantize_fused` for the native_int8 /
    packed path: returns the int8 fixed-point words (dequant = q8·2^-FL).
    Same three dispatch regimes (scalar / stacked (L,)-vector FL /
    shard_map-wrapped)."""
    seed = jnp.asarray(seed, jnp.int32)
    fl = jnp.asarray(fl, jnp.int32)
    stacked = bool(fl.ndim)
    if use_pallas:
        on_tpu = _on_tpu()

        def call(xv, sv, flv):
            if stacked:
                return _sq.sr_quantize_fused_stacked_int8(
                    xv, sv, flv, interpret=not on_tpu, hw_prng=on_tpu)
            return _sq.sr_quantize_fused_int8(xv, sv, flv,
                                              interpret=not on_tpu,
                                              hw_prng=on_tpu)

        if sharding is not None:
            return _fused_sharded(x, seed, (fl,), (stacked,), call, sharding)
        return call(x, seed, fl)
    if sharding is not None:
        raise ValueError("sr_quantize_fused_int8: sharding= requires "
                         "use_pallas=True (the XLA fallback would gather "
                         "the master; use the noise+constraint path "
                         "instead)")
    if stacked:
        b = (fl.shape[0],) + (1,) * (x.ndim - 1)
        return ref.ref_sr_quantize_fused_int8(x, seed, fl.reshape(b))
    return ref.ref_sr_quantize_fused_int8(x, seed, fl)


def edf_ladder_hists(w: Array, fls: Array, r, *, wl_ladder: tuple,
                     r_upr: int, use_pallas: bool = False) -> Array:
    """(1+T, r_upr) master + per-WL-candidate histograms in one data pass."""
    if use_pallas:
        return _el.edf_ladder_hists(w, fls, jnp.asarray(r, jnp.int32),
                                    wl_ladder=wl_ladder, r_upr=r_upr,
                                    interpret=not _on_tpu())
    return ref.ref_edf_ladder_hists(w, fls, jnp.asarray(r, jnp.int32),
                                    wl_ladder=wl_ladder, r_upr=r_upr)


def fxp_matmul(x: Array, wq: Array, scale: Array, *, use_pallas: bool = False,
               bias: Array | None = None) -> Array:
    """Differentiable on both paths: the Pallas route carries a custom VJP
    whose backward matmuls are themselves Pallas kernels (dx streams the
    same int8 weight tiles through a transposed index map; dw accumulates
    xᵀ@dy in f32 VMEM scratch), so jax.grad never falls back to a
    dequantized HBM weight copy.

    Masking contract: ANY ⟨M,K,N⟩ is accepted — primes included. Blocks
    are the requested size clamped to the dim (never a whole-dim
    fallback), grids are ``pl.cdiv``, and partial boundary blocks are
    correct by construction: the forward and both backward kernels zero
    the contracted-dim tail lanes in-register before each MXU
    accumulation and zero-fill the valid slice on boundary writes
    (Pallas pads partial blocks with garbage/NaN). Aligned shapes trace
    to the exact unmasked kernels, so the masking is free there."""
    if use_pallas:
        out = _fm.fxp_matmul_vjp(x, wq, scale, interpret=not _on_tpu())
        if bias is not None:
            out = out + bias
        return out
    return ref.ref_fxp_matmul(x, wq, scale, bias)


def int8_matmul(xq: Array, wq: Array, sx: Array, sw: Array, *,
                use_pallas: bool = False) -> Array:
    if use_pallas:
        return _fm.int8_matmul_vjp(xq, wq, sx, sw, interpret=not _on_tpu())
    return ref.ref_int8_matmul(xq, wq, sx, sw)


def fxp_dense(x: Array, wq: Array, scale: Array, wref: Array, *,
              use_pallas: bool = False, out_dtype=None) -> Array:
    """The model's dense layer over MATERIALIZED int8 words (the packed
    ⟨q8, sc, wref⟩ container): differentiable with the straight-through
    weight cotangent — dx streams the same int8 tiles (transposed index
    map), dw = xᵀ@dy lands whole on ``wref`` (→ the master param via
    ``controller.strip_packed_grads``), and the scale gets a ZERO cotangent
    (it is controller state, exactly ``fixed_point.dequant_packed``'s
    rule) — so flipping dispatch never changes the optimizer step. The
    non-Pallas path is the XLA dequant-then-dot this replaces."""
    if use_pallas:
        return _fm.fxp_dense_vjp(x, wq, scale, wref, out_dtype=out_dtype,
                                 interpret=not _on_tpu())
    wv = wq.astype(jnp.float32) * jax.lax.stop_gradient(
        scale.astype(jnp.float32).reshape(())) + wref.astype(jnp.float32)
    out = jnp.dot(x.astype(jnp.float32), wv,
                  preferred_element_type=jnp.float32)
    return out.astype(out_dtype or x.dtype)


def fxp_qdense(x: Array, w: Array, seed: Array, fl: Array, mode: Array, *,
               use_pallas: bool = False, out_dtype=None) -> Array:
    """Quantize-PROLOGUE dense layer: consumes the float MASTER weight +
    ⟨FL, seed, mode⟩ and quantizes tiles in-register en route to the MXU —
    the int8 words only ever exist in VMEM (no q8 HBM round trip on
    freshly re-quantized layers). mode: 1 = SR (portable index-hash
    stream, bit-identical to ``sr_quantize_fused_int8`` for a 2-D leaf),
    0 = RTN (round-half-even, bit-identical to the XLA packed path).
    Straight-through: dw = xᵀ@dy lands directly on ``w`` (the master)."""
    seed = jnp.asarray(seed, jnp.int32)
    fl = jnp.asarray(fl, jnp.int32)
    mode = jnp.asarray(mode, jnp.int32)
    if use_pallas:
        return _fm.fxp_qdense_vjp(x, w, seed, fl, mode,
                                  out_dtype=out_dtype,
                                  interpret=not _on_tpu())
    return ref.ref_fxp_qdense(x, w, seed, fl, mode).astype(out_dtype
                                                           or x.dtype)


def kl_hist(w: Array, q: Array, num_bins: int = 256, *,
            use_pallas: bool = False) -> Array:
    if use_pallas:
        return _kh.kl_hist(w, q, num_bins=num_bins, interpret=not _on_tpu())
    return ref.ref_kl_hist(w, q, num_bins)


def attention(q: Array, k: Array, v: Array, *, causal: bool = True,
              window: int = 0, softcap: float = 0.0,
              scale: float | None = None, use_pallas: bool = False,
              bq: int = 512, bk: int = 512) -> Array:
    """Differentiable on both paths: the Pallas route carries a custom VJP
    (forward stashes the per-row logsumexp; backward is the standard
    recompute scheme as two more Pallas kernels, kernels/flash_attention
    ``_flash_dq_kernel`` / ``_flash_dkv_kernel``), so the differentiated
    training forward keeps the flash kernel instead of materializing the
    (Sq × Skv) logits in XLA.

    Masking contract: ANY Sq/Skv is accepted — primes included. bq/bk are
    clamped (never widened to the whole sequence), grids stay ``pl.cdiv``
    multi-block, and the garbage padding of partial boundary blocks is
    tail-masked inside all three kernels: q/k tail lanes read NEG_INF in
    the score path (excluded from the softmax max, the logsumexp and the
    per-row D), padded k/v/do lanes are zeroed before every MXU
    contraction, and boundary writes carry zeros in the padding lanes.
    Aligned shapes trace to the exact unmasked kernels (zero overhead);
    causal/window/GQA masking composes with the tail mask through the one
    shared ``_block_mask``."""
    if use_pallas:
        return _fa.flash_attention_vjp(q, k, v, causal=causal, window=window,
                                       softcap=softcap, scale=scale,
                                       bq=bq, bk=bk, interpret=not _on_tpu())
    return ref.ref_attention(q, k, v, causal=causal, window=window,
                             softcap=softcap, scale=scale)
