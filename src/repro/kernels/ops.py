"""Jit'd dispatch wrappers over the Pallas kernels.

Each op picks the Pallas path on TPU (or when forced) and falls back to the
pure-jnp oracle otherwise; `interpret=True` is used automatically on CPU so
the kernels stay exercised (and tested) in this container.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import fxp_matmul as _fm
from repro.kernels import kl_hist as _kh
from repro.kernels import ref
from repro.kernels import sr_quantize as _sq

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def sr_quantize(x: Array, u: Array, wl, fl, *, use_pallas: bool = False) -> Array:
    if use_pallas:
        return _sq.sr_quantize(x, u, jnp.asarray(wl, jnp.int32),
                               jnp.asarray(fl, jnp.int32),
                               interpret=not _on_tpu())
    return ref.ref_sr_quantize(x, u, wl, fl)


def fxp_matmul(x: Array, wq: Array, scale: Array, *, use_pallas: bool = False,
               bias: Array | None = None) -> Array:
    if use_pallas:
        out = _fm.fxp_matmul(x, wq, scale, interpret=not _on_tpu())
        if bias is not None:
            out = out + bias
        return out
    return ref.ref_fxp_matmul(x, wq, scale, bias)


def int8_matmul(xq: Array, wq: Array, sx: Array, sw: Array, *,
                use_pallas: bool = False) -> Array:
    if use_pallas:
        return _fm.int8_matmul(xq, wq, sx, sw, interpret=not _on_tpu())
    return ref.ref_int8_matmul(xq, wq, sx, sw)


def kl_hist(w: Array, q: Array, num_bins: int = 256, *,
            use_pallas: bool = False) -> Array:
    if use_pallas:
        return _kh.kl_hist(w, q, num_bins=num_bins, interpret=not _on_tpu())
    return ref.ref_kl_hist(w, q, num_bins)


def attention(q: Array, k: Array, v: Array, *, causal: bool = True,
              window: int = 0, softcap: float = 0.0,
              scale: float | None = None, use_pallas: bool = False,
              bq: int = 512, bk: int = 512) -> Array:
    if use_pallas:
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   softcap=softcap, scale=scale, bq=bq, bk=bk,
                                   interpret=not _on_tpu())
    return ref.ref_attention(q, k, v, causal=causal, window=window,
                             softcap=softcap, scale=scale)
