"""Pallas TPU kernel: fused PushDown EDF ladder — all WL-candidate histograms
in one pass over the weights.

PushDown (alg. 3) compares the master weights' EDF against the EDF of the
weights re-quantized at every candidate word length. The XLA reference does
this as |ladder| = 18 independent quantize passes, each followed by *two*
scatter-add histograms (``jnp.zeros(bins).at[idx].add(1)``) — 18 reads of the
tensor and 36 scatters, the single most TPU-hostile pattern in the repo.

This kernel streams each (block_rows, 128) tile of the pre-subsampled weights
through VMEM **once** and, per tile:

  * bins the master values into the (T+1, r_upr) accumulator's row 0,
  * for each ladder candidate t (static unroll — WLs are compile-time, the
    range-derived FLs arrive per-call via SMEM): round-to-nearest quantizes
    the tile in-register and bins it into row 1+t,

with binning done MXU-style as a one-hot (elements × bins) matmul-reduce
exactly like ``kl_hist`` — no scatters anywhere. The live resolution r^l
(runtime, SMEM) masks down the static r_upr-bin buffer; padding lanes are
masked by global element index so every histogram is exact. One launch
replaces 18 quantize+histogram round trips; the KL/argmin epilogue over the
(T+1, r_upr) counts is O(T·r_upr) scalar work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.sr_quantize import _pow2i

Array = jax.Array

LANE = 128


def _edf_ladder_kernel(scal_ref, meta_ref, fls_ref, x_ref, o_ref, acc_ref, *,
                       wl_ladder: tuple, r_upr: int, nsteps: int,
                       block_rows: int, cols: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    lo = scal_ref[0, 0]
    hi = scal_ref[0, 1]
    rf = meta_ref[0, 0].astype(jnp.float32)   # live bin count r^l
    n = meta_ref[0, 1]                        # valid element count
    span = jnp.maximum(hi - lo, 1e-12)
    bins = jax.lax.broadcasted_iota(jnp.float32, (1, r_upr), 1)

    row0 = pl.program_id(0) * block_rows
    r = jax.lax.broadcasted_iota(jnp.int32, (block_rows, cols), 0)
    c = jax.lax.broadcasted_iota(jnp.int32, (block_rows, cols), 1)
    valid = (((row0 + r) * cols + c) < n).astype(jnp.float32).reshape(-1, 1)

    x = x_ref[...].astype(jnp.float32)

    def count(v):
        # same expression order as pushdown._histogram for bit parity
        idx = jnp.clip(jnp.floor((v - lo) / span * rf),
                       0, rf - 1).astype(jnp.float32).reshape(-1, 1)
        onehot = (idx == bins).astype(jnp.float32) * valid
        return jnp.sum(onehot, axis=0)

    acc_ref[0, :] += count(x)
    for t, wl in enumerate(wl_ladder):        # static unroll over the ladder
        scale = _pow2i(fls_ref[0, t])   # exact: exp2 is off an ulp at FL≳10
        qmax = float(2.0 ** (wl - 1) - 1.0)
        q = jnp.clip(jnp.round(x * scale), -qmax - 1.0, qmax) / scale
        acc_ref[1 + t, :] += count(q)

    @pl.when(pl.program_id(0) == nsteps - 1)
    def _done():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("wl_ladder", "r_upr",
                                             "block_rows", "interpret"))
def edf_ladder_hists(w: Array, fls: Array, r: Array, *, wl_ladder: tuple,
                     r_upr: int, block_rows: int = 64,
                     interpret: bool = False) -> Array:
    """Counts (1+T, r_upr): row 0 the master EDF of ``w``, row 1+t the EDF of
    ``w`` round-to-nearest quantized at ⟨wl_ladder[t], fls[t]⟩ — all over w's
    [min, max] range with ``r`` live bins inside the static r_upr buffer.

    w: 1-D pre-subsampled f32 weights; fls: (T,) int32 range-derived FLs;
    r: int32 live resolution.
    """
    wf = w.reshape(-1).astype(jnp.float32)
    n = wf.shape[0]
    cols = LANE
    if n >= 2 ** 31 - cols:                   # int32 element-index math
        raise ValueError(f"edf_ladder_hists: {n} elements overflow int32 "
                         "indexing — subsample first (pushdown.subsample)")
    lo, hi = jnp.min(wf), jnp.max(wf)
    rows = pl.cdiv(n, cols)
    pad = rows * cols - n
    w2 = jnp.pad(wf, (0, pad)).reshape(rows, cols)
    scal = jnp.stack([lo, hi]).reshape(1, 2)
    meta = jnp.stack([jnp.asarray(r, jnp.int32),
                      jnp.int32(n)]).reshape(1, 2)
    fls2 = fls.astype(jnp.int32).reshape(1, -1)
    T = len(wl_ladder)

    grid = (pl.cdiv(rows, block_rows),)
    kernel = functools.partial(_edf_ladder_kernel, wl_ladder=wl_ladder,
                               r_upr=r_upr, nsteps=grid[0],
                               block_rows=block_rows, cols=cols)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),      # lo/hi (f32)
            pl.BlockSpec(memory_space=pltpu.SMEM),      # r, n (int32)
            pl.BlockSpec(memory_space=pltpu.SMEM),      # per-candidate FLs
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1 + T, r_upr), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1 + T, r_upr), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1 + T, r_upr), jnp.float32)],
        interpret=interpret,
    )(scal, meta, fls2, w2)
