"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``ref_*`` function is the semantic ground truth the kernels are tested
against (tests/test_kernels.py sweeps shapes/dtypes and asserts allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def _pow2(e) -> Array:
    """Exact 2^e (f32) for integer(-valued) e, via ldexp. Grid scales must
    never go through a transcendental lowering — XLA CPU's exp2 (and
    potentially pow) is off an ulp for |e| ≳ 10, which would knock the
    oracles off the exact ⟨WL,FL⟩ grid the kernels (sr_quantize._pow2i)
    guarantee."""
    return jnp.ldexp(jnp.float32(1.0), jnp.asarray(e, jnp.int32))


def ref_sr_quantize(x: Array, u: Array, wl: int, fl: int) -> Array:
    """Fixed-point ⟨WL,FL⟩ stochastic-round quantize (f32-container grid)."""
    xf = x.astype(jnp.float32)
    scale = _pow2(fl)
    qmax = _pow2(wl - 1) - 1.0
    s = xf * scale
    f = jnp.floor(s)
    q = f + (u.astype(jnp.float32) < (s - f)).astype(jnp.float32)
    q = jnp.clip(q, -qmax - 1.0, qmax)
    return (q / scale).astype(x.dtype)


def ref_sr_quantize_fused(x: Array, seed: Array, wl: int, fl: int) -> Array:
    """Oracle for the in-kernel-PRNG variant: same grid semantics, noise
    drawn from jax.random keyed on ``seed``. Deterministic per seed but a
    *different* stream than the kernel's (hardware or counter-hash) PRNG —
    parity with the kernel is distributional, not bitwise."""
    u = jax.random.uniform(jax.random.PRNGKey(seed), x.shape, jnp.float32)
    return ref_sr_quantize(x, u, wl, fl)


def ref_sr_quantize_fused_int8(x: Array, seed: Array, fl: int) -> Array:
    """Oracle for the int8-word flavor (int8 storage clip, WL≤8 by mode)."""
    u = jax.random.uniform(jax.random.PRNGKey(seed), x.shape, jnp.float32)
    xf = x.astype(jnp.float32) * jnp.float32(2.0) ** fl
    f = jnp.floor(xf)
    q = f + (u < (xf - f)).astype(jnp.float32)
    return jnp.clip(q, -128.0, 127.0).astype(jnp.int8)


# ---------------------------------------------------------------------------
# Bit-exact oracles of the fused kernels' PORTABLE noise stream.
#
# The fused kernels draw noise in-register. On compiled TPU that is the
# hardware PRNG (not reproducible off-device); everywhere else — interpret
# mode, i.e. CPU CI and any non-TPU backend — it is a murmur3-finalizer
# counter hash over the global padded element index. That stream is a
# CONTRACT: the functions below regenerate it in pure jnp so the
# differential harness (tests/test_quantize_differential.py) can demand
# word-for-word equality with the kernels, and the golden-stream test can
# pin it against drift. Padding in the kernels' (rows, 512) layout only
# appends elements at the end of each flat plane, so the live stream of an
# unstacked tensor is simply hash(0..n-1) and layer l of a stacked tensor
# starts at flat offset l·rows·512.

FUSED_LANES = 512          # the fused kernels' padded row width (LANE * 4)


def ref_fused_noise(seed, n: int, offset: int = 0) -> Array:
    """U[0,1) words the fused kernels draw for flat padded elements
    [offset, offset + n) under the portable counter-hash stream."""
    h = (jnp.arange(n, dtype=jnp.uint32) + jnp.uint32(offset)
         + jnp.asarray(seed, jnp.int32).astype(jnp.uint32)
         * jnp.uint32(0x9E3779B9))
    h ^= h >> 16
    h = h * jnp.uint32(0x7FEB352D)
    h ^= h >> 15
    h = h * jnp.uint32(0x846CA68B)
    h ^= h >> 16
    return (h >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def ref_fold_shard_seed(seed, idx) -> Array:
    """Mirror of ``sr_quantize.fold_shard_seed`` (independent jnp
    implementation): the per-shard seed the shard_map wrapper derives from
    the linear shard index."""
    s = (jnp.asarray(seed, jnp.int32).astype(jnp.uint32)
         + jnp.asarray(idx, jnp.uint32) * jnp.uint32(0x9E3779B9))
    s = s ^ (s >> 16)
    s = s * jnp.uint32(0x7FEB352D)
    s = s ^ (s >> 15)
    return jax.lax.bitcast_convert_type(s, jnp.int32)


def ref_sr_quantize_fused_words(x: Array, seed, wl, fl) -> Array:
    """Bit-exact oracle of ``sr_quantize_fused`` under the portable stream
    (vs :func:`ref_sr_quantize_fused`, which is only distributional)."""
    u = ref_fused_noise(seed, x.size).reshape(x.shape)
    return ref_sr_quantize(x, u, wl, fl)


def ref_sr_quantize_fused_int8_words(x: Array, seed, fl) -> Array:
    """Bit-exact oracle of ``sr_quantize_fused_int8``'s portable stream."""
    u = ref_fused_noise(seed, x.size).reshape(x.shape)
    xf = x.astype(jnp.float32) * _pow2(fl)
    f = jnp.floor(xf)
    q = f + (u < (xf - f)).astype(jnp.float32)
    return jnp.clip(q, -128.0, 127.0).astype(jnp.int8)


def ref_qdense_words(w: Array, seed, fl, mode=1) -> Array:
    """Bit-exact oracle of the quantize-prologue word draw
    (``fxp_matmul._quantize_w_tile``): element (k, n) of a (K, N) master
    hashes its flat index k·N + n, which for a 2-D leaf is EXACTLY the
    ``sr_quantize_fused_int8`` PORTABLE stream — prologue and materialized
    words agree bit-for-bit wherever both use it (interpret mode / CPU
    CI; on compiled TPU the materialized kernel draws from the hardware
    PRNG instead, so there the dispatches agree in distribution only).
    ``mode`` 1 = SR, 0 = RTN (round-half-even, matching ``jnp.round``
    on every backend)."""
    xf = w.astype(jnp.float32) * _pow2(fl)
    u = ref_fused_noise(seed, w.size).reshape(w.shape)
    f = jnp.floor(xf)
    q_sr = f + (u < (xf - f)).astype(jnp.float32)
    q = jnp.where(jnp.asarray(mode) == 1, q_sr, jnp.round(xf))
    return jnp.clip(q, -128.0, 127.0).astype(jnp.int8)


def ref_fxp_qdense(x: Array, w: Array, seed, fl, mode=1) -> Array:
    """Forward oracle of ``fxp_qmatmul``: x @ (words · 2^-fl) with the
    straight-through view (differentiating this gives dx through the
    dequantized words and dw = xᵀ@dy onto the master — the same cotangents
    the Pallas VJP produces)."""
    words = jax.lax.stop_gradient(
        ref_qdense_words(w, seed, fl, mode).astype(jnp.float32))
    wv = w + jax.lax.stop_gradient(words * _pow2(-fl) - w)
    acc = jnp.dot(x.astype(jnp.float32), wv.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    return acc.astype(x.dtype)


def _stacked_offsets(x: Array):
    n = x[0].size
    rows = -(-n // FUSED_LANES)
    return n, rows * FUSED_LANES


def ref_sr_quantize_fused_stacked_words(x: Array, seed, wl, fl) -> Array:
    """Bit-exact oracle of ``sr_quantize_fused_stacked``: slice l on the
    ⟨wl[l], fl[l]⟩ grid, noise from flat offset l·rows·512 of the shared
    stream."""
    n, stride = _stacked_offsets(x)
    outs = []
    for l in range(x.shape[0]):
        u = ref_fused_noise(seed, n, offset=l * stride)
        outs.append(ref_sr_quantize(x[l].reshape(-1), u, wl[l],
                                    fl[l]).reshape(x.shape[1:]))
    return jnp.stack(outs)


def ref_sr_quantize_fused_stacked_int8_words(x: Array, seed, fl) -> Array:
    """Bit-exact oracle of ``sr_quantize_fused_stacked_int8``."""
    n, stride = _stacked_offsets(x)
    outs = []
    for l in range(x.shape[0]):
        u = ref_fused_noise(seed, n, offset=l * stride)
        xf = x[l].reshape(-1).astype(jnp.float32) * _pow2(fl[l])
        f = jnp.floor(xf)
        q = f + (u < (xf - f)).astype(jnp.float32)
        outs.append(jnp.clip(q, -128.0, 127.0).astype(jnp.int8)
                    .reshape(x.shape[1:]))
    return jnp.stack(outs)


def ref_sr_quantize_fused_sharded_words(x: Array, seed, wl, fl,
                                        grid: tuple, *,
                                        int8: bool = False) -> Array:
    """Bit-exact oracle of the shard_map-wrapped fused quantize, assembled
    on one device: ``grid[d]`` equal blocks per dim; block b (row-major
    over ``grid``, matching the wrapper's flattened-axis fold order)
    quantizes with seed ``ref_fold_shard_seed(seed, b)`` and its own local
    padded-layout stream. wl/fl may be scalars or (L,) vectors (stacked
    leaf — dim-0 blocks then carry the matching precision slice)."""
    import itertools
    blocks = [s // g for s, g in zip(x.shape, grid)]
    stacked = bool(jnp.ndim(fl))
    out = jnp.zeros(x.shape, jnp.int8 if int8 else x.dtype)
    for lin, coords in enumerate(itertools.product(
            *[range(g) for g in grid])):
        sl = tuple(slice(c * b, (c + 1) * b)
                   for c, b in zip(coords, blocks))
        s = ref_fold_shard_seed(seed, lin)
        blk = x[sl]
        if int8:
            q = (ref_sr_quantize_fused_stacked_int8_words(blk, s, fl[sl[0]])
                 if stacked else ref_sr_quantize_fused_int8_words(blk, s, fl))
        else:
            q = (ref_sr_quantize_fused_stacked_words(blk, s, wl[sl[0]],
                                                     fl[sl[0]])
                 if stacked else ref_sr_quantize_fused_words(blk, s, wl, fl))
        out = out.at[sl].set(q)
    return out


def ref_edf_ladder_hists(w: Array, fls: Array, r: Array, *, wl_ladder: tuple,
                         r_upr: int) -> Array:
    """Oracle for the fused EDF ladder: scatter-add histograms of the master
    weights and each round-to-nearest ⟨WL,FL⟩-requantized candidate, r live
    bins inside a static r_upr buffer over w's [min, max] range."""
    wf = w.reshape(-1).astype(jnp.float32)
    lo, hi = jnp.min(wf), jnp.max(wf)
    span = jnp.maximum(hi - lo, 1e-12)
    rf = r.astype(jnp.float32)

    def hist(x):
        idx = jnp.clip(jnp.floor((x - lo) / span * rf),
                       0, rf - 1).astype(jnp.int32)
        return jnp.zeros((r_upr,), jnp.float32).at[idx].add(1.0)

    rows = [hist(wf)]
    for t, wl in enumerate(wl_ladder):
        scale = _pow2(fls[t])
        qmax = jnp.float32(2.0 ** (wl - 1) - 1.0)
        q = jnp.clip(jnp.round(wf * scale), -qmax - 1.0, qmax) / scale
        rows.append(hist(q))
    return jnp.stack(rows)


def ref_fxp_matmul(x: Array, wq: Array, scale: Array,
                   bias: Array | None = None) -> Array:
    """x @ (wq * scale) with f32 accumulation.

    x: (M, K) float; wq: (K, N) int8 fixed-point words; scale: () or (N,) f32.
    """
    acc = jnp.dot(x.astype(jnp.float32), wq.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    out = acc * scale.astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def ref_int8_matmul(xq: Array, wq: Array, sx: Array, sw: Array) -> Array:
    """Full int8×int8→int32 path: (xq @ wq) * sx * sw, f32 out."""
    acc = jax.lax.dot_general(
        xq, wq, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * sx.astype(jnp.float32) * sw.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Backward-pass oracles (ground truth for the custom-VJP Pallas kernels —
# tests/test_vjp_differential.py additionally checks against raw XLA
# autodiff of the forward oracles, so these stay closed-form and readable).


def ref_matmul_dx(dy: Array, wq: Array, scale: Array) -> Array:
    """dx = dy @ (wq·scale)ᵀ, f32 accumulation, dy.dtype out."""
    acc = jnp.dot(dy.astype(jnp.float32), wq.astype(jnp.float32).T,
                  preferred_element_type=jnp.float32)
    return (acc * scale.astype(jnp.float32)).astype(dy.dtype)


def ref_matmul_dw(x: Array, dy: Array) -> Array:
    """dw = xᵀ @ dy in f32."""
    return jnp.dot(x.astype(jnp.float32).T, dy.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def ref_fxp_matmul_grads(x: Array, wq: Array, scale: Array, dy: Array):
    """(dx, dscale) cotangents of ``ref_fxp_matmul`` (dwq is float0 —
    the int8 words are non-differentiable storage)."""
    dw = ref_matmul_dw(x, dy)
    dscale = (jnp.sum(dw * wq.astype(jnp.float32))
              .reshape(jnp.shape(scale)).astype(scale.dtype))
    return ref_matmul_dx(dy, wq, scale).astype(x.dtype), dscale


def ref_int8_matmul_grads(xq: Array, wq: Array, sx: Array, sw: Array,
                          dy: Array):
    """(dsx, dsw) cotangents of ``ref_int8_matmul``."""
    acc = jax.lax.dot_general(
        xq, wq, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32).astype(jnp.float32)
    g0 = jnp.sum(dy.astype(jnp.float32) * acc)
    return ((g0 * sw.astype(jnp.float32)).reshape(jnp.shape(sx)),
            (g0 * sx.astype(jnp.float32)).reshape(jnp.shape(sw)))


def ref_attention_lse(q: Array, k: Array, v: Array, *, causal: bool = True,
                      window: int = 0, softcap: float = 0.0,
                      scale: float | None = None) -> Array:
    """Per-row logsumexp (B, H, Sq) of the masked (softcapped) logits —
    the residual flash_attention(return_lse=True) stashes for its VJP."""
    B, Sq, H, D = q.shape
    _, Skv, Hkv, _ = k.shape
    rep = H // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
    sc = scale if scale is not None else (1.0 / D ** 0.5)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * sc
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    return jax.scipy.special.logsumexp(logits, axis=-1)


def ref_attention_grads(q: Array, k: Array, v: Array, dy: Array, **kwargs):
    """(dq, dk, dv) via XLA autodiff of :func:`ref_attention` — the oracle
    the Pallas backward kernels are pinned against."""
    _, vjp = jax.vjp(lambda a, b, c: ref_attention(a, b, c, **kwargs),
                     q, k, v)
    return vjp(dy)


def ref_kl_hist(w: Array, q: Array, num_bins: int) -> Array:
    """Fused double histogram: counts (2, num_bins) of w and q over w's range."""
    wf = w.astype(jnp.float32).reshape(-1)
    qf = q.astype(jnp.float32).reshape(-1)
    lo, hi = jnp.min(wf), jnp.max(wf)
    span = jnp.maximum(hi - lo, 1e-12)

    def hist(x):
        idx = jnp.clip(jnp.floor((x - lo) / span * num_bins),
                       0, num_bins - 1).astype(jnp.int32)
        return jnp.zeros((num_bins,), jnp.float32).at[idx].add(1.0)

    return jnp.stack([hist(wf), hist(qf)])


def ref_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                  window: int = 0, softcap: float = 0.0,
                  scale: float | None = None) -> Array:
    """Multi-head attention oracle.

    q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D). GQA via head-group broadcast.
    window > 0: sliding-window causal mask. softcap > 0: tanh logit cap.
    """
    B, Sq, H, D = q.shape
    _, Skv, Hkv, _ = k.shape
    rep = H // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    sc = scale if scale is not None else (1.0 / D ** 0.5)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * sc
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)   # align ends (decode-friendly)
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
