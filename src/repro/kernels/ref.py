"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``ref_*`` function is the semantic ground truth the kernels are tested
against (tests/test_kernels.py sweeps shapes/dtypes and asserts allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def ref_sr_quantize(x: Array, u: Array, wl: int, fl: int) -> Array:
    """Fixed-point ⟨WL,FL⟩ stochastic-round quantize (f32-container grid)."""
    xf = x.astype(jnp.float32)
    scale = jnp.float32(2.0) ** fl
    qmax = jnp.float32(2.0) ** (wl - 1) - 1.0
    s = xf * scale
    f = jnp.floor(s)
    q = f + (u.astype(jnp.float32) < (s - f)).astype(jnp.float32)
    q = jnp.clip(q, -qmax - 1.0, qmax)
    return (q / scale).astype(x.dtype)


def ref_sr_quantize_fused(x: Array, seed: Array, wl: int, fl: int) -> Array:
    """Oracle for the in-kernel-PRNG variant: same grid semantics, noise
    drawn from jax.random keyed on ``seed``. Deterministic per seed but a
    *different* stream than the kernel's (hardware or counter-hash) PRNG —
    parity with the kernel is distributional, not bitwise."""
    u = jax.random.uniform(jax.random.PRNGKey(seed), x.shape, jnp.float32)
    return ref_sr_quantize(x, u, wl, fl)


def ref_sr_quantize_fused_int8(x: Array, seed: Array, fl: int) -> Array:
    """Oracle for the int8-word flavor (int8 storage clip, WL≤8 by mode)."""
    u = jax.random.uniform(jax.random.PRNGKey(seed), x.shape, jnp.float32)
    xf = x.astype(jnp.float32) * jnp.float32(2.0) ** fl
    f = jnp.floor(xf)
    q = f + (u < (xf - f)).astype(jnp.float32)
    return jnp.clip(q, -128.0, 127.0).astype(jnp.int8)


def ref_edf_ladder_hists(w: Array, fls: Array, r: Array, *, wl_ladder: tuple,
                         r_upr: int) -> Array:
    """Oracle for the fused EDF ladder: scatter-add histograms of the master
    weights and each round-to-nearest ⟨WL,FL⟩-requantized candidate, r live
    bins inside a static r_upr buffer over w's [min, max] range."""
    wf = w.reshape(-1).astype(jnp.float32)
    lo, hi = jnp.min(wf), jnp.max(wf)
    span = jnp.maximum(hi - lo, 1e-12)
    rf = r.astype(jnp.float32)

    def hist(x):
        idx = jnp.clip(jnp.floor((x - lo) / span * rf),
                       0, rf - 1).astype(jnp.int32)
        return jnp.zeros((r_upr,), jnp.float32).at[idx].add(1.0)

    rows = [hist(wf)]
    for t, wl in enumerate(wl_ladder):
        scale = jnp.exp2(fls[t].astype(jnp.float32))
        qmax = jnp.float32(2.0 ** (wl - 1) - 1.0)
        q = jnp.clip(jnp.round(wf * scale), -qmax - 1.0, qmax) / scale
        rows.append(hist(q))
    return jnp.stack(rows)


def ref_fxp_matmul(x: Array, wq: Array, scale: Array,
                   bias: Array | None = None) -> Array:
    """x @ (wq * scale) with f32 accumulation.

    x: (M, K) float; wq: (K, N) int8 fixed-point words; scale: () or (N,) f32.
    """
    acc = jnp.dot(x.astype(jnp.float32), wq.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    out = acc * scale.astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def ref_int8_matmul(xq: Array, wq: Array, sx: Array, sw: Array) -> Array:
    """Full int8×int8→int32 path: (xq @ wq) * sx * sw, f32 out."""
    acc = jax.lax.dot_general(
        xq, wq, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * sx.astype(jnp.float32) * sw.astype(jnp.float32)


def ref_kl_hist(w: Array, q: Array, num_bins: int) -> Array:
    """Fused double histogram: counts (2, num_bins) of w and q over w's range."""
    wf = w.astype(jnp.float32).reshape(-1)
    qf = q.astype(jnp.float32).reshape(-1)
    lo, hi = jnp.min(wf), jnp.max(wf)
    span = jnp.maximum(hi - lo, 1e-12)

    def hist(x):
        idx = jnp.clip(jnp.floor((x - lo) / span * num_bins),
                       0, num_bins - 1).astype(jnp.int32)
        return jnp.zeros((num_bins,), jnp.float32).at[idx].add(1.0)

    return jnp.stack([hist(wf), hist(qf)])


def ref_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                  window: int = 0, softcap: float = 0.0,
                  scale: float | None = None) -> Array:
    """Multi-head attention oracle.

    q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D). GQA via head-group broadcast.
    window > 0: sliding-window causal mask. softcap > 0: tanh logit cap.
    """
    B, Sq, H, D = q.shape
    _, Skv, Hkv, _ = k.shape
    rep = H // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    sc = scale if scale is not None else (1.0 / D ** 0.5)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * sc
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)   # align ends (decode-friendly)
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
