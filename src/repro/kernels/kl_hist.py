"""Pallas TPU kernel: fused double histogram for the PushDown KL probe.

PushDown (alg. 3) needs counts of the master weights *and* their quantized
counterpart over the same bin grid. A scatter-add histogram is hostile to the
TPU vector unit; instead each tile builds a one-hot (elements × bins) matrix
and reduces it with the MXU — bins ≤ r_upr ≤ 256 so the one-hot tile fits
VMEM, and both histograms are produced in a single pass over the data
(the XLA fallback reads the tensor twice and scatters).

lo/hi (the master tensor's range) arrive via SMEM so the kernel is reusable
across the PushDown WL ladder without recompilation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

LANE = 128


def _kl_hist_kernel(range_ref, w_ref, q_ref, o_ref, acc_ref, *, num_bins: int,
                    nsteps: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    lo = range_ref[0, 0]
    hi = range_ref[0, 1]
    inv_span = num_bins / jnp.maximum(hi - lo, 1e-12)
    bins = jax.lax.broadcasted_iota(jnp.float32, (1, num_bins), 1)

    def count(x_tile):
        idx = jnp.clip(jnp.floor((x_tile - lo) * inv_span),
                       0, num_bins - 1).astype(jnp.float32).reshape(-1, 1)
        onehot = (idx == bins).astype(jnp.float32)      # (elems, bins)
        return jnp.sum(onehot, axis=0)                  # (bins,)

    acc_ref[0, :] += count(w_ref[...].astype(jnp.float32))
    acc_ref[1, :] += count(q_ref[...].astype(jnp.float32))

    @pl.when(pl.program_id(0) == nsteps - 1)
    def _done():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("num_bins", "block_rows",
                                             "interpret"))
def kl_hist(w: Array, q: Array, *, num_bins: int = 256, block_rows: int = 64,
            interpret: bool = False) -> Array:
    """Counts (2, num_bins) of ``w`` and ``q`` over w's [min, max] range.

    Lane padding is FILLED with ``lo`` (w's minimum) so every pad element
    deterministically bins to index 0 regardless of the tensor's range;
    the known pad count is then subtracted from bin 0 of both histograms.
    """
    wf = w.reshape(-1).astype(jnp.float32)
    qf = q.reshape(-1).astype(jnp.float32)
    n = wf.shape[0]
    lo, hi = jnp.min(wf), jnp.max(wf)
    cols = LANE
    rows = pl.cdiv(n, cols)
    pad = rows * cols - n
    # pad with lo -> lands in bin 0; corrected below
    w2 = jnp.pad(wf, (0, pad), constant_values=0.0).reshape(rows, cols)
    q2 = jnp.pad(qf, (0, pad), constant_values=0.0).reshape(rows, cols)
    w2 = jnp.where(jnp.arange(rows * cols).reshape(rows, cols) < n, w2, lo)
    q2 = jnp.where(jnp.arange(rows * cols).reshape(rows, cols) < n, q2, lo)
    rng = jnp.stack([lo, hi]).reshape(1, 2)

    grid = (pl.cdiv(rows, block_rows),)
    kernel = functools.partial(_kl_hist_kernel, num_bins=num_bins,
                               nsteps=grid[0])
    counts = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((2, num_bins), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((2, num_bins), jnp.float32),
        scratch_shapes=[pltpu.VMEM((2, num_bins), jnp.float32)],
        interpret=interpret,
    )(rng, w2, q2)
    # remove padding contribution from bin 0 of both histograms
    return counts - jnp.array([[float(pad)] + [0.0] * (num_bins - 1)] * 2,
                              jnp.float32)
