"""Pallas TPU kernel: blocked (flash) attention forward.

The 32k-prefill shapes are attention-dominated: naive attention materializes
a (Sq × Skv) = 32k×32k f32 logits tensor per head (4 GB) — far beyond VMEM
and a pure HBM-bandwidth disaster. This kernel runs the standard online-
softmax block scheme: for each (batch, head, q-block) the (m, l, acc) state
stays in VMEM while kv-blocks stream through, so HBM traffic is O(S·D)
instead of O(S²).

Features needed by the assigned archs, all fused:
  * causal masking with end-alignment (decode/prefill-with-cache friendly)
  * sliding-window masking (mixtral SWA, gemma2 local layers)
  * logit softcapping   (gemma2: softcap · tanh(logits / softcap))
  * GQA via kv-head index mapping (no jnp.repeat materialization)

Grid: (B, H, nq, nk), kv innermost ("arbitrary"), MXU-aligned q/kv blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import tpu_compiler_params

Array = jax.Array

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, softcap: float,
                  bq: int, bk: int, nk: int, q_offset: int):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)          # (bk, D)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    logits *= scale
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)

    qpos = (iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            + q_offset)                           # absolute key-space position
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_cur = jnp.max(logits, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(logits - m_new)                   # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)               # (bq, 1)
    l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == nk - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "scale", "bq", "bk", "interpret"))
def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int = 0, softcap: float = 0.0,
                    scale: float | None = None, bq: int = 512, bk: int = 512,
                    interpret: bool = False) -> Array:
    """q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D); returns (B, Sq, H, D).

    Query positions are aligned to the *end* of the key space
    (q_offset = Skv − Sq), matching prefill-with-cache and decode semantics.
    """
    B, Sq, H, D = q.shape
    _, Skv, Hkv, _ = k.shape
    rep = H // Hkv
    sc = scale if scale is not None else (1.0 / D ** 0.5)
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    nq, nk = pl.cdiv(Sq, bq), pl.cdiv(Skv, bk)

    qt = q.transpose(0, 2, 1, 3)                  # (B, H, Sq, D)
    kt = k.transpose(0, 2, 1, 3)                  # (B, Hkv, Skv, D)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel, scale=sc, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, nk=nk, q_offset=Skv - Sq)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
