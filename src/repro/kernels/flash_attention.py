"""Pallas TPU kernel: blocked (flash) attention forward.

The 32k-prefill shapes are attention-dominated: naive attention materializes
a (Sq × Skv) = 32k×32k f32 logits tensor per head (4 GB) — far beyond VMEM
and a pure HBM-bandwidth disaster. This kernel runs the standard online-
softmax block scheme: for each (batch, head, q-block) the (m, l, acc) state
stays in VMEM while kv-blocks stream through, so HBM traffic is O(S·D)
instead of O(S²).

Features needed by the assigned archs, all fused:
  * causal masking with end-alignment (decode/prefill-with-cache friendly)
  * sliding-window masking (mixtral SWA, gemma2 local layers)
  * logit softcapping   (gemma2: softcap · tanh(logits / softcap))
  * GQA via kv-head index mapping (no jnp.repeat materialization)

Grid: (B, H, ⌈Sq/bq⌉, ⌈Skv/bk⌉), kv innermost ("arbitrary"). MXU-aligned
q/kv blocks preferred but NOT required: non-divisible Sq/Skv produce
partial boundary blocks whose garbage padding is tail-masked in-kernel —
q/k tail lanes are NEG_INF in the score path (excluded from max/logsumexp
and every backward contraction, via the shared ``_block_mask``) and the
padded k/v/do lanes are zeroed before any MXU contraction.

The forward optionally emits the per-row logsumexp (``return_lse``) — the
residual the recompute-based backward (``flash_attention_vjp``) needs. The
backward precomputes the tiny per-row D = Σ dy∘o (one XLA elementwise
pass; o is not an operand of either launch) and then runs two more Pallas
kernels over the same block scheme: ``_dq`` re-derives the probabilities
from the stashed lse, ``_dkv`` accumulates dK/dV tiles with the q-loop
innermost — the rep query heads of each GQA group fold into the same
accumulators, so HBM only ever sees (B, Hkv, Skv, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import tpu_compiler_params
from repro.kernels.fxp_matmul import _clamp_block, _mask_tail

Array = jax.Array

NEG_INF = -1e30


def _positions(iq: int, ik: int, bq: int, bk: int, q_offset: int):
    """Absolute key-space positions of a (bq, bk) block: queries are
    end-aligned (q_offset = Skv − Sq)."""
    qpos = (iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            + q_offset)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    return qpos, kpos


def _block_mask(iq, ik, *, bq: int, bk: int, causal: bool, window: int,
                q_offset: int, sq: int, skv: int):
    """The ONE causal/sliding-window/tail mask both the forward and the
    backward recompute share — any inclusivity change here stays
    bit-identical across o, lse and dQ/dK/dV.

    ``sq``/``skv`` are the TRUE sequence extents: on boundary blocks of a
    non-divisible grid the q/k tail lanes hold Pallas garbage padding, so
    they are masked out of the score matrix (NEG_INF downstream — excluded
    from the softmax max, the logsumexp, and every backward contraction).
    Statically free when the grid tiles both dims evenly."""
    qpos, kpos = _positions(iq, ik, bq, bk, q_offset)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if sq % bq:
        mask &= qpos - q_offset < sq          # q-tail rows of the block
    if skv % bk:
        mask &= kpos < skv                    # k-tail cols of the block
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    return mask


def _flash_kernel(q_ref, k_ref, v_ref, *refs,
                  scale: float, causal: bool, window: int, softcap: float,
                  bq: int, bk: int, nk: int, q_offset: int, sq: int,
                  skv: int, with_lse: bool):
    if with_lse:
        o_ref, lse_ref, m_ref, l_ref, acc_ref = refs
    else:
        (o_ref, m_ref, l_ref, acc_ref), lse_ref = refs, None
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (bq, D)
    # kv tails: k garbage only reaches masked logit columns, but v rides
    # p @ v where the masked p entries are exact zeros — 0·NaN = NaN, so
    # both tails are zeroed before any contraction (no-ops when aligned).
    k = _mask_tail(k_ref[0, 0].astype(jnp.float32), 0, ik, skv)   # (bk, D)
    v = _mask_tail(v_ref[0, 0].astype(jnp.float32), 0, ik, skv)   # (bk, D)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    logits *= scale
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)

    mask = _block_mask(iq, ik, bq=bq, bk=bk, causal=causal, window=window,
                       q_offset=q_offset, sq=sq, skv=skv)
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_cur = jnp.max(logits, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(logits - m_new)                   # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)               # (bq, 1)
    l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == nk - 1)
    def _done():
        # Rows with NO surviving key (Sq > Skv under causal end-alignment)
        # keep m = NEG_INF: exp(NEG_INF − NEG_INF) would average v
        # uniformly, a meaningless row the backward cannot reconstruct
        # from the lse — emit exactly 0 (and lse = NEG_INF) instead, so
        # forward and VJP agree that the row is constant.
        dead = m_ref[...] <= NEG_INF * 0.5
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = jnp.where(dead, 0.0,
                                acc_ref[...] / l).astype(o_ref.dtype)
        if lse_ref is not None:
            lse_ref[0, 0] = jnp.where(dead, NEG_INF,
                                      m_ref[...] + jnp.log(l))[:, 0]


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "scale", "bq", "bk", "interpret",
                                             "return_lse"))
def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int = 0, softcap: float = 0.0,
                    scale: float | None = None, bq: int = 512, bk: int = 512,
                    interpret: bool = False, return_lse: bool = False):
    """q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D); returns (B, Sq, H, D).

    Query positions are aligned to the *end* of the key space
    (q_offset = Skv − Sq), matching prefill-with-cache and decode semantics.
    ``return_lse`` additionally returns the per-row logsumexp (B, H, Sq)
    f32 — the backward pass's residual. Rows whose mask admits no key at
    all (Sq > Skv under causal alignment) are exactly 0 with lse = NEG_INF
    — flash convention, and what the VJP assumes (ref_attention instead
    softmaxes the all-masked row into a uniform average).

    Any Sq/Skv is accepted: bq/bk are clamped (never widened to a
    whole-dim block) and partial boundary blocks are tail-masked
    in-kernel, so grids stay multi-block with VMEM bounded by the
    requested blocks even for prime sequence lengths.
    """
    B, Sq, H, D = q.shape
    _, Skv, Hkv, _ = k.shape
    rep = H // Hkv
    sc = scale if scale is not None else (1.0 / D ** 0.5)
    bq = _clamp_block(bq, Sq)
    bk = _clamp_block(bk, Skv)
    nq, nk = pl.cdiv(Sq, bq), pl.cdiv(Skv, bk)

    qt = q.transpose(0, 2, 1, 3)                  # (B, H, Sq, D)
    kt = k.transpose(0, 2, 1, 3)                  # (B, Hkv, Skv, D)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel, scale=sc, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, nk=nk, q_offset=Skv - Sq,
        sq=Sq, skv=Skv, with_lse=return_lse)

    out_shape = [jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype)]
    out_specs = [pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0))]
    if return_lse:
        out_shape.append(jax.ShapeDtypeStruct((B, H, Sq), jnp.float32))
        out_specs.append(pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)))

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(qt, kt, vt)
    o = out[0].transpose(0, 2, 1, 3)
    return (o, out[1]) if return_lse else o


# ---------------------------------------------------------------------------
# Backward kernels (recompute-based, standard flash scheme)


def _block_probs(q, k, lse, iq, ik, *, scale, causal, window, softcap,
                 bq, bk, q_offset, sq, skv):
    """Recompute the (bq, bk) probability block p = exp(t − lse) from the
    stashed logsumexp, plus the pre-mask softcapped logits t (needed for
    the tanh chain). Masked entries — including q/k tail lanes of partial
    boundary blocks — are exactly 0 (no NEG_INF arithmetic, so fully-
    masked rows can't poison the accumulators with inf·0). Callers must
    hand in tail-sanitized q/k so t itself stays finite (the softcap tanh
    chain multiplies by (1 − (t/cap)²) AFTER the p zeros are in place)."""
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale
    t = softcap * jnp.tanh(s / softcap) if softcap > 0.0 else s
    mask = _block_mask(iq, ik, bq=bq, bk=bk, causal=causal, window=window,
                       q_offset=q_offset, sq=sq, skv=skv)
    p = jnp.where(mask, jnp.exp(t - lse[:, None]), 0.0)
    return p, t


def _grad_wrt_logits(p, dp, delta, t, *, softcap):
    """dt = p∘(dp − D); chain through the softcap tanh back to the raw
    (pre-cap, post-scale) logits."""
    dt = p * (dp - delta)
    if softcap > 0.0:
        dt = dt * (1.0 - jnp.square(t / softcap))
    return dt


def _flash_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, d_ref, dq_ref,
                     acc_ref, *, scale: float, causal: bool,
                     window: int, softcap: float, bq: int, bk: int, nk: int,
                     q_offset: int, sq: int, skv: int):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Tail-sanitize every streamed operand (static no-ops when aligned):
    # the masked p/g entries are exact zeros, but g @ k and do @ vᵀ still
    # touch the garbage k/v tail lanes (0·NaN = NaN), and q/do/delta tails
    # keep t and dp finite so the softcap chain can't reintroduce NaNs.
    q = _mask_tail(q_ref[0, 0].astype(jnp.float32), 0, iq, sq)
    k = _mask_tail(k_ref[0, 0].astype(jnp.float32), 0, ik, skv)
    v = _mask_tail(v_ref[0, 0].astype(jnp.float32), 0, ik, skv)
    do = _mask_tail(do_ref[0, 0].astype(jnp.float32), 0, iq, sq)
    delta = _mask_tail(d_ref[0, 0][:, None], 0, iq, sq)
    p, t = _block_probs(q, k, lse_ref[0, 0], iq, ik, scale=scale,
                        causal=causal, window=window, softcap=softcap,
                        bq=bq, bk=bk, q_offset=q_offset, sq=sq, skv=skv)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    g = _grad_wrt_logits(p, dp, delta, t, softcap=softcap)
    acc_ref[...] += jax.lax.dot_general(
        g, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _done():
        dq_ref[0, 0] = _mask_tail(acc_ref[...] * scale, 0, iq,
                                  sq).astype(dq_ref.dtype)


def _flash_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, d_ref,
                      dk_ref, dv_ref, dk_acc, dv_acc, *, scale: float,
                      causal: bool, window: int, softcap: float, bq: int,
                      bk: int, nq: int, nj: int, q_offset: int, sq: int,
                      skv: int):
    # Grid dim 3 runs (rep · nq) steps head-major: j = r·nq + iq. The rep
    # query heads of the GQA group fold into the SAME (bk, D) accumulators,
    # so the kernel writes the group-summed dK/dV tiles directly — never a
    # rep×-sized per-query-head cotangent in HBM.
    ik, j = pl.program_id(2), pl.program_id(3)
    iq = jax.lax.rem(j, nq)

    @pl.when(j == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    # Here BOTH contractions run over the q rows (pᵀ @ do, gᵀ @ q), so the
    # q/do/delta tails must be exact zeros — and the k/v tails likewise,
    # or the masked-p zeros meet garbage through dp (0·NaN = NaN). All
    # static no-ops on aligned grids.
    q = _mask_tail(q_ref[0, 0].astype(jnp.float32), 0, iq, sq)
    k = _mask_tail(k_ref[0, 0].astype(jnp.float32), 0, ik, skv)
    v = _mask_tail(v_ref[0, 0].astype(jnp.float32), 0, ik, skv)
    do = _mask_tail(do_ref[0, 0].astype(jnp.float32), 0, iq, sq)
    delta = _mask_tail(d_ref[0, 0][:, None], 0, iq, sq)
    p, t = _block_probs(q, k, lse_ref[0, 0], iq, ik, scale=scale,
                        causal=causal, window=window, softcap=softcap,
                        bq=bq, bk=bk, q_offset=q_offset, sq=sq, skv=skv)
    dv_acc[...] += jax.lax.dot_general(
        p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    g = _grad_wrt_logits(p, dp, delta, t, softcap=softcap)
    dk_acc[...] += jax.lax.dot_general(
        g, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(j == nj - 1)
    def _done():
        # kv-tail rows of the accumulators are exact zeros by construction
        # (every contribution above is tail-masked), so the boundary write
        # is already zero-filled.
        dk_ref[0, 0] = dk_acc[...] * scale
        dv_ref[0, 0] = dv_acc[...]


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "scale", "bq", "bk", "interpret"))
def flash_attention_bwd(q: Array, k: Array, v: Array, o: Array, lse: Array,
                        do: Array, *, causal: bool = True, window: int = 0,
                        softcap: float = 0.0, scale: float | None = None,
                        bq: int = 512, bk: int = 512,
                        interpret: bool = False):
    """dQ/dK/dV for :func:`flash_attention` given the stashed (o, lse).

    Per-row D = Σ dy∘o is a tiny (B, H, Sq) f32 precompute (one fused XLA
    elementwise pass — o is not an operand of either kernel launch), then
    two launches: dQ with the kv loop innermost (one (bq, D) f32
    accumulator), and dK/dV gridded over KV heads with the (rep · nq)
    q-blocks of the whole GQA group innermost, group-summing in VMEM.
    """
    B, Sq, H, D = q.shape
    _, Skv, Hkv, _ = k.shape
    rep = H // Hkv
    sc = scale if scale is not None else (1.0 / D ** 0.5)
    bq = _clamp_block(bq, Sq)
    bk = _clamp_block(bk, Skv)
    nq, nk = pl.cdiv(Sq, bq), pl.cdiv(Skv, bk)

    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    dot = do.transpose(0, 2, 1, 3)
    delta = jnp.sum(dot.astype(jnp.float32)
                    * o.transpose(0, 2, 1, 3).astype(jnp.float32), axis=-1)

    qspec = pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0))
    lspec = pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i))

    dq = pl.pallas_call(
        functools.partial(_flash_dq_kernel, scale=sc, causal=causal,
                          window=window, softcap=softcap, bq=bq, bk=bk,
                          nk=nk, q_offset=Skv - Sq, sq=Sq, skv=Skv),
        grid=(B, H, nq, nk),
        in_specs=[
            qspec,
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
            qspec, lspec, lspec,
        ],
        out_specs=qspec,
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(qt, kt, vt, dot, lse, delta)

    # dK/dV: grid over KV heads and kv blocks; the innermost dim runs
    # (rep · nq) steps — the q blocks of every query head in the GQA group
    # — folding the group-sum into the kernel's own accumulation, so only
    # the real (B, Hkv, Skv, D) cotangents ever reach HBM.
    def _qh(h, j, r=rep, n=nq):
        return h * r + j // n
    qjspec = pl.BlockSpec((1, 1, bq, D),
                          lambda b, h, i, j: (b, _qh(h, j), j % nq, 0))
    ljspec = pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, _qh(h, j),
                                                          j % nq))
    kvjspec = pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, i, 0))
    dkv_out = pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, i, 0))
    dk, dv = pl.pallas_call(
        functools.partial(_flash_dkv_kernel, scale=sc, causal=causal,
                          window=window, softcap=softcap, bq=bq, bk=bk,
                          nq=nq, nj=nq * rep, q_offset=Skv - Sq,
                          sq=Sq, skv=Skv),
        grid=(B, Hkv, nk, nq * rep),
        in_specs=[qjspec, kvjspec, kvjspec, qjspec, ljspec, ljspec],
        out_specs=[dkv_out, dkv_out],
        out_shape=[jax.ShapeDtypeStruct((B, Hkv, Skv, D), jnp.float32),
                   jax.ShapeDtypeStruct((B, Hkv, Skv, D), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bk, D), jnp.float32),
                        pltpu.VMEM((bk, D), jnp.float32)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(qt, kt, vt, dot, lse, delta)

    return (dq.transpose(0, 2, 1, 3),
            dk.astype(k.dtype).transpose(0, 2, 1, 3),
            dv.astype(v.dtype).transpose(0, 2, 1, 3))


# ---------------------------------------------------------------------------
# custom_vjp


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash_diff(cfg, q, k, v):
    causal, window, softcap, scale, bq, bk, interpret = cfg
    return flash_attention(q, k, v, causal=causal, window=window,
                           softcap=softcap, scale=scale, bq=bq, bk=bk,
                           interpret=interpret)


def _flash_diff_fwd(cfg, q, k, v):
    causal, window, softcap, scale, bq, bk, interpret = cfg
    o, lse = flash_attention(q, k, v, causal=causal, window=window,
                             softcap=softcap, scale=scale, bq=bq, bk=bk,
                             interpret=interpret, return_lse=True)
    return o, (q, k, v, o, lse)


def _flash_diff_bwd(cfg, res, do):
    causal, window, softcap, scale, bq, bk, interpret = cfg
    q, k, v, o, lse = res
    return flash_attention_bwd(q, k, v, o, lse, do, causal=causal,
                               window=window, softcap=softcap, scale=scale,
                               bq=bq, bk=bk, interpret=interpret)


_flash_diff.defvjp(_flash_diff_fwd, _flash_diff_bwd)


def flash_attention_vjp(q: Array, k: Array, v: Array, *, causal: bool = True,
                        window: int = 0, softcap: float = 0.0,
                        scale: float | None = None, bq: int = 512,
                        bk: int = 512, interpret: bool = False) -> Array:
    """Differentiable :func:`flash_attention`: same forward kernel (plus the
    lse stash under differentiation), Pallas recompute-based backward."""
    return _flash_diff((causal, window, softcap, scale, bq, bk, interpret),
                       q, k, v)
