"""QSGD-style gradient compression for the slow cross-pod links.

The paper cites QSGD [35] as the related-work answer to *communication*
quantization; AdaPT itself only quantizes compute. On a 2-pod (512-chip)
mesh the pod-crossing all-reduce runs over data-center interconnect at a
fraction of ICI bandwidth, so we extend the paper's quantization theme to
that boundary: gradients are stochastically quantized to int8 (per-tensor
max-norm scaling, unbiased) before the `psum` over the "pod" axis and
dequantized after — 4× fewer bytes over the slowest link.

Unbiasedness: E[encode(g)] = g (stochastic rounding), so SGD convergence
guarantees carry over (Alistarh et al., 2017).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def encode(g: Array, key: Array, bits: int = 8) -> Tuple[Array, Array]:
    """Stochastically quantize to signed ``bits`` integers + f32 scale.

    Returns (q int8, scale) with E[q * scale] == g.
    """
    levels = float(2 ** (bits - 1) - 1)
    gf = g.astype(jnp.float32)
    amax = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-30)
    x = gf / amax * levels
    f = jnp.floor(x)
    u = jax.random.uniform(key, g.shape, jnp.float32)
    q = f + (u < (x - f)).astype(jnp.float32)
    q = jnp.clip(q, -levels - 1, levels).astype(jnp.int8)
    return q, (amax / levels).astype(jnp.float32)


def decode(q: Array, scale: Array, dtype=jnp.float32) -> Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def psum_compressed(grads, key: Array, axis_name: str, bits: int = 8):
    """All-reduce a gradient pytree over ``axis_name`` with int8 payload.

    Each participant contributes an int8 tensor + f32 scale; the psum of the
    *dequantized* values is numerically identical to summing dequantized
    payloads pairwise (scales differ per participant, so we reduce in f32
    after local dequant — the wire format is the int8 payload).
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    out = []
    for i, g in enumerate(leaves):
        q, s = encode(g, jax.random.fold_in(key, i), bits)
        # int8 payload crosses the link; dequant-then-psum models the
        # receiver-side decode+accumulate of QSGD.
        out.append(jax.lax.psum(decode(q, s), axis_name))
    return jax.tree_util.tree_unflatten(treedef, out)
