"""repro: AdaPT (Adaptive Precision Training) as a multi-pod JAX framework."""
__version__ = "1.0.0"
