"""Quantization-friendly initialization (paper §3.1).

Fan-in truncated-normal variance scaling (TNVS):

    W^l ~ N(mu=0, sigma=sqrt(s / n_in)), truncated at ±sqrt(3 s / n_in)

The paper found TNVS-initialized nets degrade least under fixed-point
quantized training. ``s`` is the empirically chosen scale factor.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def fan_in(shape, kind: str = "linear") -> int:
    """n_in for a weights tensor. linear: (in, out) or (L, in, out);
    conv: (kh, kw, cin, cout); embed: (vocab, d) -> d is fan-in of the lookup."""
    if kind == "conv":
        kh, kw, cin = shape[-4], shape[-3], shape[-2]
        return kh * kw * cin
    if kind == "embed":
        return shape[-1]
    return shape[-2]


def tnvs(key: Array, shape, *, scale: float = 1.0, kind: str = "linear",
         dtype=jnp.float32) -> Array:
    n = max(fan_in(shape, kind), 1)
    sigma = (scale / n) ** 0.5
    bound = (3.0 * scale / n) ** 0.5
    w = sigma * jax.random.truncated_normal(
        key, -bound / sigma, bound / sigma, shape, jnp.float32)
    return w.astype(dtype)


def zeros(shape, dtype=jnp.float32) -> Array:
    return jnp.zeros(shape, dtype)


def ones(shape, dtype=jnp.float32) -> Array:
    return jnp.ones(shape, dtype)
