"""Sparsifying regularization (paper §3.4, "Inducing Sparsity").

    L̂(W) = L + α‖W‖₁ + (β/2)‖W‖₂² + P,   P = Σ_l (WL^l / 32) · sp^l

L1 drives small weights toward zero (they then quantize to exact zeros at low
FL); the P penalty charges the model for word length × density, discouraging
learning steps that need wider words or denser tensors. WL and sp enter P with
stop_gradient (they are discrete controller outputs, not differentiable).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core.controller import path_str

Array = jax.Array


def elastic_net(params, alpha: float, beta: float, quantized_paths) -> Array:
    """α Σ‖W‖₁ + β/2 Σ‖W‖₂² over quantized tensors only."""
    total = jnp.float32(0.0)
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        if path_str(path) not in quantized_paths:
            continue
        w = leaf.astype(jnp.float32)
        total = total + alpha * jnp.sum(jnp.abs(w)) + 0.5 * beta * jnp.sum(w * w)
    return total


def wordlength_penalty(adapt_state: Dict[str, Any], max_wl: int = 32) -> Array:
    """P = mean_l (WL^l/32 · sp^l); mean (not sum) keeps the coefficient
    architecture-size independent."""
    terms = []
    for ts in adapt_state["tensors"].values():
        wl = jax.lax.stop_gradient(ts["wl"]).astype(jnp.float32)
        sp = jax.lax.stop_gradient(ts["sp"])
        terms.append(jnp.mean(wl / float(max_wl) * sp))
    if not terms:
        return jnp.float32(0.0)
    return jnp.mean(jnp.stack(terms))


def adapt_loss(task_loss: Array, params, adapt_state, *, alpha: float,
               beta: float, penalty_coef: float, max_wl: int = 32) -> Array:
    reg = elastic_net(params, alpha, beta, set(adapt_state["tensors"].keys()))
    pen = penalty_coef * wordlength_penalty(adapt_state, max_wl)
    return task_loss + reg + pen
