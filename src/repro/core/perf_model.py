"""Analytical performance model (paper §4.1.2, eq. 6–9).

The paper evaluates AdaPT's speedup/size/memory with an analytical model
(fixed-point hardware was unavailable to the authors too): per-layer MAdds
weighted by word length and non-zero fraction, plus AdaPT's own overhead.

    costs_train ≤ Σ_i Σ_l ops^l · (sp_i^l · WL_i^l + 32/accs)           (8)
    ops_pd ≤ 2·log2(32−8)·r · 3 · Π dims                               (6)
    ops_pu ≤ (lb+1)·Π dims + 1                                          (7)
    costs_AdaPT ≤ Σ_i Σ_l 32 · (sp·ops_pd + ops_pu)/(accs·lb)           (9)

    SU  = (bs_other · costs_other) / (bs_ours · costs_ours)
    sz  = Σ_l sp_n^l · WL_n^l ;  SZ = sz_other / sz_ours
    mem = (Σ_i Σ_l sp_i^l·WL_i^l + 32) / n ;  MEM = mem_other / mem_ours

All inputs come from training telemetry: per-step {path: (wl, sp, lb, r)}
snapshots plus static per-tensor op counts.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Sequence

FULL_WL = 32.0


@dataclass
class LayerOps:
    """Static per-tensor characteristics: MAdds per forward pass and #params."""
    ops: float
    params: float


@dataclass
class StepTelemetry:
    """One training step's AdaPT snapshot: per tensor (wl, sp, lb, r)."""
    wl: Dict[str, float]
    sp: Dict[str, float]
    lb: Dict[str, float]
    r: Dict[str, float]


def train_costs(layer_ops: Dict[str, LayerOps], telemetry: Sequence[StepTelemetry],
                accs: int = 1) -> float:
    """Eq. 8: quantized sparse forward + float32 backward (amortized by accs)."""
    total = 0.0
    for t in telemetry:
        for path, lo in layer_ops.items():
            wl = t.wl.get(path, FULL_WL)
            sp = t.sp.get(path, 1.0)
            total += lo.ops * (sp * wl + FULL_WL / accs)
    return total


def adapt_overhead(layer_ops: Dict[str, LayerOps],
                   telemetry: Sequence[StepTelemetry], accs: int = 1) -> float:
    """Eq. 6, 7, 9."""
    total = 0.0
    for t in telemetry:
        for path, lo in layer_ops.items():
            r = t.r.get(path, 50.0)
            lb = max(t.lb.get(path, 25.0), 1.0)
            sp = t.sp.get(path, 1.0)
            dims = lo.params
            ops_pd = 2.0 * math.log2(FULL_WL - 8.0) * r * 3.0 * dims
            ops_pu = (lb + 1.0) * dims + 1.0
            total += FULL_WL * (sp * ops_pd + ops_pu) / (accs * lb)
    return total


def float32_costs(layer_ops: Dict[str, LayerOps], n_steps: int,
                  accs: int = 1) -> float:
    """Same model, dense float32 forward+backward baseline."""
    per_step = sum(lo.ops * (FULL_WL + FULL_WL / accs) for lo in layer_ops.values())
    return per_step * n_steps


def inference_costs(layer_ops: Dict[str, LayerOps], final: StepTelemetry) -> float:
    """Forward only, quantized + sparse."""
    return sum(lo.ops * final.sp.get(p, 1.0) * final.wl.get(p, FULL_WL)
               for p, lo in layer_ops.items())


def float32_inference_costs(layer_ops: Dict[str, LayerOps]) -> float:
    return sum(lo.ops * FULL_WL for lo in layer_ops.values())


def speedup(costs_other: float, costs_ours: float, bs_other: float = 1.0,
            bs_ours: float = 1.0) -> float:
    return (bs_other * costs_other) / max(bs_ours * costs_ours, 1e-30)


def model_size(layer_ops: Dict[str, LayerOps], final: StepTelemetry) -> float:
    """sz = Σ_l sp^l · WL^l (relative units; dims cancel in the ratio)."""
    return sum(final.sp.get(p, 1.0) * final.wl.get(p, FULL_WL) * lo.params
               for p, lo in layer_ops.items())


def float32_model_size(layer_ops: Dict[str, LayerOps]) -> float:
    return sum(FULL_WL * lo.params for lo in layer_ops.values())


def avg_memory(layer_ops: Dict[str, LayerOps],
               telemetry: Sequence[StepTelemetry]) -> float:
    """mem: quantized copy + float32 master, averaged over training (the +32
    term is the master copy the paper charges AdaPT for)."""
    if not telemetry:
        return 0.0
    tot = 0.0
    for t in telemetry:
        tot += sum((t.sp.get(p, 1.0) * t.wl.get(p, FULL_WL) + FULL_WL) * lo.params
                   for p, lo in layer_ops.items())
    return tot / len(telemetry)


def float32_avg_memory(layer_ops: Dict[str, LayerOps]) -> float:
    return sum(FULL_WL * lo.params for lo in layer_ops.values())


def summarize(layer_ops: Dict[str, LayerOps], telemetry: List[StepTelemetry],
              accs: int = 1, bs_ours: float = 1.0, bs_other: float = 1.0) -> Dict[str, float]:
    """All paper metrics vs the float32 baseline in one dict."""
    n = len(telemetry)
    ours = train_costs(layer_ops, telemetry, accs) + adapt_overhead(
        layer_ops, telemetry, accs)
    base = float32_costs(layer_ops, n, accs)
    final = telemetry[-1]
    return {
        "SU_train": speedup(base, ours, bs_other, bs_ours),
        "SU_infer": speedup(float32_inference_costs(layer_ops),
                            inference_costs(layer_ops, final)),
        "SZ": model_size(layer_ops, final) / max(float32_model_size(layer_ops), 1e-30),
        # paper convention (tab. 3/4 + fig. 7): MEM = mem_ours / mem_f32 > 1
        # (the f32 master copy makes AdaPT *heavier* during training; the
        # advantage is speed + the quantized final model)
        "MEM": avg_memory(layer_ops, telemetry) / max(float32_avg_memory(layer_ops), 1e-30),
        "avg_wl": sum(final.wl.values()) / max(len(final.wl), 1),
        "avg_sp": sum(final.sp.values()) / max(len(final.sp), 1),
    }
