"""MuPPET baseline (paper §2.2) — the comparison system AdaPT is evaluated
against, implemented so the benchmark tables have a real baseline.

MuPPET: block-floating-point quantization with a *global* word length WL^net
and per-layer scale factors, precision switched *upward only* between epochs
by an inter-epoch gradient-diversity ratio test. Quantization levels are a
fixed ladder (the MuPPET paper uses 8→12→14→16 → float32).

    s = | log2 min((UB+0.5)/X_max, (LB-0.5)/X_min) |        (per-layer scale)
    x_q = floor(x · 2^s + Unif(-0.5, 0.5))                  (stochastic)
    Δs(w)^j = Σ_l [ Σ_k ‖∇f_l^k‖² / ‖Σ_k ∇f_l^k‖² ] / |L|   (epoch j, window r)
    p = max S(j) / Δs(w)^j ;  switch when p > threshold ρ times
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

Array = jax.Array

LADDER = (8, 12, 14, 16, 32)  # 32 == float32 final level


def block_fp_scale(x: Array, wl: int) -> Array:
    """Per-tensor shared exponent s (paper eq. in §2.2)."""
    ub = 2.0 ** (wl - 1) - 1.0
    lb = -(2.0 ** (wl - 1))
    xmax = jnp.maximum(jnp.max(x), 1e-12)
    xmin = jnp.minimum(jnp.min(x), -1e-12)
    s = jnp.log2(jnp.minimum((ub + 0.5) / xmax, (lb - 0.5) / xmin))
    return jnp.abs(jnp.floor(s))


def quantize_block_fp(x: Array, wl: int, u: Array | None = None) -> Array:
    """Block-floating-point quantize with shared scale; float32 container."""
    if wl >= 32:
        return x.astype(jnp.float32)
    from repro.core import fixed_point as fxp
    s = block_fp_scale(x, wl)
    scale = fxp.pow2i(s)   # exact power of two (s is integer-valued)
    noise = (u - 0.5) if u is not None else 0.0
    q = jnp.floor(x.astype(jnp.float32) * scale + 0.5 + noise)
    q = jnp.clip(q, -(2.0 ** (wl - 1)), 2.0 ** (wl - 1) - 1.0)
    return q / scale


def init_state(num_layers: int, r: int = 3, threshold: float = 1.15,
               violations_needed: int = 2) -> Dict[str, Any]:
    return {
        "level": jnp.int32(0),                  # index into LADDER
        "epoch_in_level": jnp.int32(0),
        "violations": jnp.int32(0),
        "norm_sq_sum": jnp.zeros((num_layers,), jnp.float32),
        "diversity_hist": jnp.zeros((64,), jnp.float32),
        "hist_len": jnp.int32(0),
        "threshold": jnp.float32(threshold),
        "violations_needed": jnp.int32(violations_needed),
        "r": jnp.int32(r),
    }


def epoch_diversity(norm_sq_sum: Array, grad_sum_norm_sq: Array) -> Array:
    """Σ_l ‖·‖²/‖Σ·‖² / |L| from per-layer accumulators."""
    per_layer = norm_sq_sum / jnp.maximum(grad_sum_norm_sq, 1e-30)
    return jnp.mean(per_layer)


def end_of_epoch(state: Dict[str, Any], diversity: Array) -> Dict[str, Any]:
    """Inter-epoch switch decision: p = max S(j) / Δs^j > τ counts a violation;
    `violations_needed` violations trigger a level-up (never down)."""
    h = state["diversity_hist"]
    n = state["hist_len"]
    h = jax.lax.dynamic_update_index_in_dim(h, diversity, jnp.minimum(n, 63), 0)
    n = jnp.minimum(n + 1, 64)
    mask = jnp.arange(64) < n
    smax = jnp.max(jnp.where(mask, h, -jnp.inf))
    p = smax / jnp.maximum(diversity, 1e-30)
    violated = p > state["threshold"]
    violations = jnp.where(violated, state["violations"] + 1, state["violations"])
    do_switch = violations >= state["violations_needed"]
    new_level = jnp.minimum(state["level"] + do_switch.astype(jnp.int32),
                            len(LADDER) - 1)
    return {
        **state,
        "level": new_level,
        "violations": jnp.where(do_switch, 0, violations),
        "diversity_hist": jnp.where(do_switch, jnp.zeros_like(h), h),
        "hist_len": jnp.where(do_switch, 0, n),
        "epoch_in_level": jnp.where(do_switch, 0, state["epoch_in_level"] + 1),
    }


def current_wl(state: Dict[str, Any]):
    return jnp.asarray(LADDER, jnp.int32)[state["level"]]


def quantize_params(params, state: Dict[str, Any], key: Array | None = None):
    """Quantize all >=2D leaves at the current global level (block-FP)."""
    level = jax.device_get(state["level"]).item()
    wl = LADDER[level]

    def visit(path, leaf):
        if leaf.ndim < 2 or wl >= 32:
            return leaf.astype(jnp.float32)
        u = None
        if key is not None:
            u = jax.random.uniform(jax.random.fold_in(key, abs(hash(str(path))) % (2**31)),
                                   leaf.shape, jnp.float32)
        return quantize_block_fp(leaf, wl, u)

    return jax.tree_util.tree_map_with_path(visit, params)
