"""PushUp operation + strategy/lookback/resolution adaptation (paper §3.3).

Gradient diversity over the last lb batches:
    Δs = Σ_k ‖∇f_k‖₂ / ‖Σ_k ∇f_k‖₂            (eq. 3, per layer)
    Δs̃ = log Δs if 0 < Δs < ∞ else 1           (eq. between 3 and 4)

If Δs̃ > 0 two precision-increase suggestions are combined by strategy st:
    s1 = max(⌈1 / (log Δs − 1)⌉, 1)
    s2 = max(min(32·log²Δs − 1, 32) − FL_min, 1)
    s  = min/mean/max(s1, s2)                   (eq. 4)
else s = 1.

New precision (with the paper's buffer-bit overflow guard folded in; the
paper states two slightly inconsistent update formulas — we adopt the reading
"FL = FL_min + s capped so that `buff` integer headroom bits remain, WL wraps
FL plus headroom", which satisfies both formulas' intent):
    FL = min(FL_min + s, max_wl − buff)
    WL = clip(max(WL_min, FL + 1) + buff, 2, max_wl)

Strategy adaptation (eq. 5) on the loss trend, lookback adaptation with
momentum γ, resolution adaptation when lookback saturates.

TPU adaptation: Δs is computed from windowed accumulators (Σ‖g‖ scalar +
Σg tensor) rather than a stored list of gradients — see DESIGN.md §3.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

ST_MIN, ST_MEAN, ST_MAX = 0, 1, 2


def gradient_diversity(norm_sum: Array, grad_sum_norm: Array) -> Array:
    """Δs from windowed accumulators; Δs ≥ 1 by the triangle inequality."""
    return norm_sum / jnp.maximum(grad_sum_norm, 1e-20)


def suggestions(delta_s: Array, fl_min: Array, max_wl: int = 32) -> tuple[Array, Array]:
    log_ds = jnp.log(jnp.maximum(delta_s, 1e-20))
    s1 = jnp.ceil(1.0 / jnp.where(jnp.abs(log_ds - 1.0) < 1e-6, 1e-6, log_ds - 1.0))
    s1 = jnp.maximum(s1, 1.0)
    s2 = jnp.maximum(jnp.minimum(32.0 * log_ds * log_ds - 1.0, float(max_wl))
                     - fl_min.astype(jnp.float32), 1.0)
    return s1, s2


def combine(s1: Array, s2: Array, strategy: Array) -> Array:
    """Combine suggestions under st ∈ {min, mean, max} (eq. 4)."""
    choices = jnp.stack([jnp.minimum(s1, s2),
                         jnp.ceil(0.5 * (s1 + s2)),
                         jnp.maximum(s1, s2)])
    return choices[strategy]


def push_up(wl_min: Array, fl_min: Array, delta_s: Array, strategy: Array,
            *, buff: int, max_wl: int = 32) -> tuple[Array, Array]:
    """Returns new (WL, FL) int32 for one layer/tensor."""
    log_ds = jnp.log(jnp.maximum(delta_s, 1e-20))
    s1, s2 = suggestions(delta_s, fl_min, max_wl)
    s = jnp.where(log_ds > 0.0, combine(s1, s2, strategy), 1.0)
    fl = jnp.minimum(fl_min.astype(jnp.float32) + s, float(max_wl - buff))
    wl = jnp.maximum(wl_min.astype(jnp.float32), fl + 1.0) + float(buff)
    wl = jnp.clip(wl, 2.0, float(max_wl))
    fl = jnp.clip(fl, 0.0, wl - 1.0)
    return wl.astype(jnp.int32), fl.astype(jnp.int32)


def adapt_strategy(strategy: Array, loss_avg: Array, loss_now: Array) -> Array:
    """Eq. 5: escalate (min→mean→max) while loss stagnates, reset to min when
    it improves."""
    stagnating = jnp.abs(loss_avg) <= jnp.abs(loss_now)
    escalated = jnp.minimum(strategy + 1, ST_MAX)
    return jnp.where(stagnating, escalated, ST_MIN).astype(jnp.int32)


def adapt_lookback(lb: Array, delta_s: Array, *, lb_lwr: int, lb_upr: int,
                   gamma: float) -> Array:
    """lb_new = clip(⌈lb_upr/Δs⌉) with momentum γ (paper §3.3)."""
    finite = (delta_s > 0) & jnp.isfinite(delta_s)
    lb_new = jnp.where(
        finite,
        jnp.clip(jnp.ceil(float(lb_upr) / jnp.maximum(delta_s, 1e-20)),
                 lb_lwr, lb_upr),
        float(lb_upr))
    out = jnp.ceil(lb_new * gamma + (1.0 - gamma) * lb.astype(jnp.float32))
    return jnp.clip(out, lb_lwr, lb_upr).astype(jnp.int32)


def adapt_resolution(r: Array, lb: Array, *, lb_lwr: int, lb_upr: int,
                     r_lwr: int, r_upr: int) -> Array:
    """r += 1 when lookback saturates high, r -= 1 when it saturates low."""
    delta = jnp.where(lb >= lb_upr, 1, jnp.where(lb <= lb_lwr, -1, 0))
    return jnp.clip(r + delta, r_lwr, r_upr).astype(jnp.int32)
