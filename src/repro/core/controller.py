"""PrecisionController: AdaPT per-tensor state machine (paper alg. 1 & 2).

State layout (a plain dict pytree → trivially checkpointable):

    state = {
      "tensors": { path: {
          "wl":       int32 (L,) or ()     word length
          "fl":       int32 (L,) or ()     fractional length
          "lb":       int32 (L,) or ()     lookback
          "res":      int32 (L,) or ()     EDF resolution
          "count":    int32 (L,) or ()     optimizer steps in current window
          "norm_sum": f32   (L,) or ()     Σ‖g_k‖₂ over window
          "grad_sum": bf16  like param     Σ g_k over window
          "sp":       f32   (L,) or ()     non-zero fraction at last switch
      }},
      "strategy":  int32 ()                 st ∈ {0:min, 1:mean, 2:max}
      "loss_hist": f32 (H,)                 ring buffer
      "loss_ptr":  int32 ()
      "loss_seen": int32 ()
    }

Leaves with a leading scanned-layer dim L (the "blocks" stack) carry per-layer
precision; everything is vmapped over that dim. The hot ``train_step`` only
*reads* wl/fl and *writes* the accumulators; ``precision_switch`` (PushDown +
PushUp + adaptation) runs every ``adapt_interval`` steps on the same jit graph
regardless of which tensors actually switch (masked updates).
"""
from __future__ import annotations

import re
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro import sharding as shd
from repro.config import QuantConfig
from repro.core import fixed_point as fxp
from repro.core import pushdown, pushup
from repro.kernels import ops as kops
from repro.kernels.sr_quantize import fold_shard_seed

Array = jax.Array
PyTree = Any

STACKED_PREFIXES = ("blocks", "layers")


def path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def is_quantized_leaf(path: str, leaf: Array, qcfg: QuantConfig) -> bool:
    """Weights matrices/conv kernels are quantized; vectors, norms, routers,
    SSM dynamics params are not (DESIGN.md §4)."""
    if leaf.ndim < 2:
        return False
    low = path.lower()
    return not any(pat in low for pat in qcfg.exclude)


def is_stacked(path: str) -> bool:
    return path.split("/", 1)[0] in STACKED_PREFIXES


def _per_layer_shape(path: str, leaf: Array):
    return (leaf.shape[0],) if (is_stacked(path) and leaf.ndim >= 3) else ()


def _reduce_axes(path: str, leaf: Array):
    if _per_layer_shape(path, leaf):
        return tuple(range(1, leaf.ndim))
    return tuple(range(leaf.ndim))


# ---------------------------------------------------------------------------
# Init


def init_adapt_state(params: PyTree, qcfg: QuantConfig) -> Dict[str, Any]:
    tensors = {}
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in flat:
        p = path_str(path)
        if not is_quantized_leaf(p, leaf, qcfg):
            continue
        ps = _per_layer_shape(p, leaf)
        mk = lambda v, dt: jnp.full(ps, v, dt)
        tensors[p] = {
            "wl": mk(qcfg.init_wl, jnp.int32),
            "fl": mk(qcfg.init_fl, jnp.int32),
            "lb": mk(qcfg.lb_lwr, jnp.int32),
            "res": mk(qcfg.r_lwr, jnp.int32),
            "count": mk(0, jnp.int32),
            "norm_sum": mk(0.0, jnp.float32),
            "grad_sum": jnp.zeros(leaf.shape, jnp.bfloat16),
            "sp": mk(1.0, jnp.float32),
        }
    st0 = {"min": 0, "mean": 1, "max": 2}[qcfg.strategy]
    return {
        "tensors": tensors,
        "strategy": jnp.int32(st0),
        "loss_hist": jnp.zeros((qcfg.loss_hist_len,), jnp.float32),
        "loss_ptr": jnp.int32(0),
        "loss_seen": jnp.int32(0),
    }


# ---------------------------------------------------------------------------
# Per-step accumulation (cheap; lives inside train_step)


def accumulate(state: Dict[str, Any], grads: PyTree, loss: Array) -> Dict[str, Any]:
    flat = dict(
        (path_str(p), g) for p, g in jax.tree_util.tree_flatten_with_path(grads)[0])
    tensors = {}
    for path, ts in state["tensors"].items():
        g = flat[path].astype(jnp.float32)
        axes = tuple(range(1, g.ndim)) if ts["wl"].shape else tuple(range(g.ndim))
        gn = jnp.sqrt(jnp.sum(g * g, axis=axes) + 1e-30)
        tensors[path] = {
            **ts,
            "norm_sum": ts["norm_sum"] + gn,
            "grad_sum": (ts["grad_sum"].astype(jnp.float32) + g).astype(jnp.bfloat16),
            "count": ts["count"] + 1,
        }
    h = state["loss_hist"]
    ptr = state["loss_ptr"]
    h = h.at[ptr].set(loss.astype(jnp.float32))
    return {
        **state,
        "tensors": tensors,
        "loss_hist": h,
        "loss_ptr": (ptr + 1) % h.shape[0],
        "loss_seen": state["loss_seen"] + 1,
    }


# ---------------------------------------------------------------------------
# Precision switch (PushDown + PushUp, masked per tensor/layer)


def _avg_lookback(state) -> Array:
    lbs = [jnp.mean(ts["lb"].astype(jnp.float32)) for ts in state["tensors"].values()]
    return jnp.mean(jnp.stack(lbs)) if lbs else jnp.float32(0.0)


def _loss_stats(state, lb_avg: Array):
    """(avg loss over last ⌈lb_avg⌉ entries, most recent loss) from the ring."""
    h = state["loss_hist"]
    n = h.shape[0]
    ptr = state["loss_ptr"]                       # next write slot
    seen = jnp.minimum(state["loss_seen"], n)
    k = jnp.clip(jnp.ceil(lb_avg).astype(jnp.int32), 1, seen)
    idx = (ptr - 1 - jnp.arange(n)) % n           # most recent first
    vals = h[idx]
    mask = (jnp.arange(n) < k).astype(jnp.float32)
    avg = jnp.sum(vals * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return avg, vals[0]


def _switch_tensor(ts: Dict[str, Array], w: Array, strategy: Array,
                   qcfg: QuantConfig) -> Dict[str, Array]:
    """PushDown + PushUp for one tensor (possibly per-layer-stacked)."""
    per_layer = bool(ts["wl"].shape)

    def one(w_slice, wl, fl, lb, res, count, norm_sum, gsum_norm, sp):
        should = count >= lb
        ds = pushup.gradient_diversity(norm_sum, gsum_norm)
        flat = pushdown.subsample(w_slice.reshape(-1).astype(jnp.float32),
                                  qcfg.edf_sample)
        wl_min, fl_min = pushdown.push_down(
            flat, res, r_upr=qcfg.r_upr, eps_kl=qcfg.eps_kl,
            max_wl=qcfg.max_wl, use_pallas=qcfg.use_pallas)
        wl_new, fl_new = pushup.push_up(
            wl_min, fl_min, ds, strategy, buff=qcfg.buff, max_wl=qcfg.max_wl)
        lb_new = pushup.adapt_lookback(lb, ds, lb_lwr=qcfg.lb_lwr,
                                       lb_upr=qcfg.lb_upr, gamma=qcfg.gamma)
        res_new = pushup.adapt_resolution(res, lb_new, lb_lwr=qcfg.lb_lwr,
                                          lb_upr=qcfg.lb_upr,
                                          r_lwr=qcfg.r_lwr, r_upr=qcfg.r_upr)
        # measure sparsity of the quantized-at-new-precision weights
        qw = fxp.quantize(flat, wl_new, fl_new, u=None)
        sp_new = fxp.sparsity(qw)
        pick = lambda a, b: jnp.where(should, a, b)
        return (pick(wl_new, wl), pick(fl_new, fl), pick(lb_new, lb),
                pick(res_new, res), pick(jnp.int32(0), count),
                pick(jnp.float32(0.0), norm_sum), pick(sp_new, sp))

    gsum = ts["grad_sum"].astype(jnp.float32)
    if per_layer:
        axes = tuple(range(1, gsum.ndim))
        gsum_norm = jnp.sqrt(jnp.sum(gsum * gsum, axis=axes) + 1e-30)
        outs = jax.vmap(one)(w, ts["wl"], ts["fl"], ts["lb"], ts["res"],
                             ts["count"], ts["norm_sum"], gsum_norm, ts["sp"])
    else:
        gsum_norm = jnp.sqrt(jnp.sum(gsum * gsum) + 1e-30)
        outs = one(w, ts["wl"], ts["fl"], ts["lb"], ts["res"],
                   ts["count"], ts["norm_sum"], gsum_norm, ts["sp"])
    wl, fl, lb, res, count, norm_sum, sp = outs
    should = ts["count"] >= ts["lb"]
    bshape = should.shape + (1,) * (gsum.ndim - should.ndim)
    grad_sum = jnp.where(should.reshape(bshape), 0.0, gsum).astype(jnp.bfloat16)
    return {"wl": wl, "fl": fl, "lb": lb, "res": res, "count": count,
            "norm_sum": norm_sum, "grad_sum": grad_sum, "sp": sp}


def precision_switch(state: Dict[str, Any], params: PyTree,
                     qcfg: QuantConfig) -> Dict[str, Any]:
    """Alg. 2: AdaptStrategy, then per tensor Adapt{Lookback,Resolution} +
    PushDown + PushUp where the window is full."""
    lb_avg = _avg_lookback(state)
    loss_avg, loss_now = _loss_stats(state, lb_avg)
    strategy = pushup.adapt_strategy(state["strategy"], loss_avg, loss_now)

    flat = dict(
        (path_str(p), w) for p, w in jax.tree_util.tree_flatten_with_path(params)[0])
    tensors = {
        path: _switch_tensor(ts, flat[path].astype(jnp.float32), strategy, qcfg)
        for path, ts in state["tensors"].items()
    }
    return {**state, "tensors": tensors, "strategy": strategy}


# ---------------------------------------------------------------------------
# Quantized copy for the forward pass (alg. 1 ln. 9-11)


def _leaf_key(key: Array, path: str) -> Array:
    # stable per-path fold; cheap non-cryptographic hash of the path string
    h = 0
    for ch in path:
        h = (h * 131 + ord(ch)) % (2 ** 31 - 1)
    return jax.random.fold_in(key, h)


def _leaf_seed(key: Array, path: str) -> Array:
    """int32 scalar seed for the in-kernel PRNG, derived from the per-leaf
    key so determinism-per-⟨step key, path⟩ is preserved."""
    return jax.random.randint(_leaf_key(key, path), (), 0, 2 ** 31 - 1,
                              jnp.int32)


def _use_fused_prng(qcfg: QuantConfig, key, wl: Array, leaf: Array,
                    sharding=None) -> bool:
    """True when ``leaf`` can take the 2-transfer in-kernel-PRNG quantize.
    All three dispatch regimes are served by ``kernels.ops``: scalar-⟨WL,FL⟩
    leaves hit ``sr_quantize_fused`` directly; per-layer-stacked leaves
    (wl of shape (L,)) hit the stacked kernel (leading per-layer grid dim,
    SMEM precision vector); explicitly-sharded leaves are wrapped in
    ``sharding.shard_map`` with per-shard folded seeds (pallas_call has no
    SPMD partitioning rule, so without the wrapper GSPMD would REPLICATE
    the kernel and all-gather the f32 master). Remaining exclusions:

    * round-to-nearest mode (no step key / stochastic_rounding off) — the
      fused kernel is an SR kernel; RTN stays on the deterministic XLA path;
    * placements that are not a NamedSharding (no mesh/spec to map);
    * sharded leaves whose sharded dims don't divide evenly over their mesh
      axes — shard_map needs equal blocks, so those keep the XLA
      noise+constraint path."""
    if not (qcfg.use_pallas and qcfg.fused_prng and qcfg.stochastic_rounding
            and key is not None):
        return False
    if wl.ndim > 1 or (wl.ndim == 1 and wl.shape[0] != leaf.shape[0]):
        return False
    if sharding is None:
        return True
    if not isinstance(sharding, NamedSharding):
        return False
    return shd.shard_grid(leaf.shape, sharding.spec, sharding.mesh) is not None


def _use_dense_prologue(qcfg: QuantConfig, path: str, fl: Array,
                        leaf: Array, sharding=None) -> bool:
    """True when ``leaf`` should skip word materialization entirely and be
    quantized in the MATMUL PROLOGUE (``kernels/ops.fxp_qdense``): packed
    mode only, behind ``use_pallas`` + ``dense_prologue``, and only for
    leaves ``models/common.dense`` actually feeds to the kernels — a 2-D
    weight (scalar ⟨WL,FL⟩) or a per-layer-stacked (L, K, N) weight with
    an (L,)-vector precision, named in ``fixed_point.DENSE_PARAM_NAMES``.
    Everything else (embed tables, conv kernels, MoE expert einsum
    operands) keeps the materialized packed container. Works for SR (per-
    leaf/-layer seeds, portable index-hash stream) AND RTN (key=None /
    stochastic_rounding off → mode 0, bit-identical to ``jnp.round``),
    so serving takes the same path.

    EXPLICITLY-SHARDED leaves are excluded: pallas_call has no SPMD
    partitioning rule, so a prologue dict on a mesh would make GSPMD
    gather the f32 MASTER into every dense kernel launch — 4× the wire
    bytes of the 1-byte packed container those leaves keep instead
    (whose q8 payload is what the mesh moves either way). A shard_map
    wrapper for the dense matmul kernels is the open ROADMAP item."""
    if not (qcfg.use_pallas and qcfg.dense_prologue):
        return False
    if not fxp.is_dense_param(path):
        return False
    if sharding is not None:
        if not isinstance(sharding, NamedSharding):
            return False
        if any(shd.spec_dim_axes(sharding.spec, leaf.ndim)):
            return False
    if fl.ndim == 0:
        return leaf.ndim == 2
    return fl.ndim == 1 and leaf.ndim == 3 and fl.shape[0] == leaf.shape[0]


def quantize_params(params: PyTree, state: Dict[str, Any], qcfg: QuantConfig,
                    key: Array | None = None, dtype=jnp.float32,
                    shardings: PyTree | None = None) -> PyTree:
    """Return the quantized copy L̂ of the master params (grid values in a
    ``dtype`` container). Non-quantized leaves are passed through in
    ``dtype``.

    ``shardings``: optional NamedSharding tree (same structure as params).
    On the XLA path the SR noise is constrained to each tensor's sharding —
    without this GSPMD resolves (sharded master × replicated noise) by
    ALL-GATHERING the f32 master before quantizing (measured: the entire
    5.6 TiB/step arctic gather volume ran in f32 regardless of container
    dtype; §Perf). With ``use_pallas`` + ``fused_prng``, eligible leaves
    (see ``_use_fused_prng``) skip the noise tensor entirely — drawn inside
    the kernel, one fewer param-sized HBM round trip — including per-layer-
    stacked leaves (one stacked-kernel launch per leaf) and evenly-sharded
    leaves (shard_map-wrapped kernel, per-shard seeds, zero collectives).

    ``dtype=jnp.int8`` emits the native-int8 path: round(w·2^FL) lives as an
    int8 tensor in the graph (exact for WL≤8), dequantized to bf16 at the
    consumer — FSDP/TP weight movement happens on 1-byte payloads.
    """
    tensors = state["tensors"]
    int8 = dtype == jnp.int8
    out_dtype = jnp.bfloat16 if int8 else dtype
    flat_sh = None
    if shardings is not None:
        flat_sh = dict(
            (path_str(p), s) for p, s in
            jax.tree_util.tree_flatten_with_path(shardings)[0])

    def visit(path, leaf):
        p = path_str(path)
        if p not in tensors:
            return leaf.astype(out_dtype)
        ts = tensors[p]
        wl, fl = ts["wl"], ts["fl"]
        sh = flat_sh.get(p) if flat_sh is not None else None
        if _use_fused_prng(qcfg, key, wl, leaf, sh):
            # single-pass Pallas kernel, noise drawn in-register: the only
            # param-sized HBM traffic is leaf-in / quantized-out.
            seed = _leaf_seed(key, p)
            if int8:
                q8 = kops.sr_quantize_fused_int8(leaf, seed, fl,
                                                 use_pallas=True, sharding=sh)
                # exact 2^-FL (bf16-representable): bf16 exp2 is off by up
                # to ~3% and NOT a power of two — fixed_point.pow2i
                sc = fxp.pow2i(-fl).astype(jnp.bfloat16)
                if fl.shape:
                    sc = sc.reshape(fl.shape + (1,) * (leaf.ndim - 1))
                return q8.astype(jnp.bfloat16) * sc
            return kops.sr_quantize_fused(leaf, seed, wl, fl, use_pallas=True,
                                          sharding=sh).astype(out_dtype)
        if wl.shape:  # stacked: broadcast (L,) -> (L,1,...)
            bshape = wl.shape + (1,) * (leaf.ndim - 1)
            wl = wl.reshape(bshape)
            fl = fl.reshape(bshape)
        u = None
        if qcfg.stochastic_rounding and key is not None:
            u = fxp.uniform_noise_like(_leaf_key(key, p), leaf)
            if flat_sh is not None and p in flat_sh:
                u = jax.lax.with_sharding_constraint(u, flat_sh[p])
        if int8:
            scale = fxp.pow2i(fl)
            x = leaf.astype(jnp.float32) * scale
            q = fxp.stochastic_round(x, u) if u is not None else jnp.round(x)
            q = jnp.clip(q, -128.0, 127.0).astype(jnp.int8)
            return q.astype(jnp.bfloat16) * fxp.pow2i(-fl).astype(jnp.bfloat16)
        return fxp.quantize(leaf, wl, fl, u=u).astype(out_dtype)

    return jax.tree_util.tree_map_with_path(visit, params)


# ---------------------------------------------------------------------------
# Packed int8 wire format (native_int8 / §Perf): the quantized copy travels
# the mesh as int8 + per-layer scale; dequant happens AFTER the per-layer
# FSDP gather (inside the scan body), so weight movement costs 1 byte/param
# instead of 4 (f32 container) — AdaPT's low-bit forward applied to the
# *interconnect*. Gradients route through a custom_vjp to a bf16 reference
# tensor that the forward never reads (so it is DCE'd — no extra traffic).


def quantize_params_packed(params: PyTree, state: Dict[str, Any],
                           qcfg: QuantConfig, key: Array | None = None,
                           shardings: PyTree | None = None) -> PyTree:
    """Lazy packed tree: quantized leaves become {"q8", "sc", "wref"} dicts
    (see fixed_point.PACKED_KEYS); consumers call fxp.unpack_tree AT the use
    site — inside the scanned layer body, after the per-layer gather — so
    weights cross the mesh as int8 (4× less than the f32 container).
    Differentiate w.r.t. this tree: cotangents land on each "wref".

    Dense-consumed leaves (``fixed_point.is_dense_param``) under
    ``use_pallas`` + ``dense_prologue`` skip the word materialization
    entirely: they come back as quantize-PROLOGUE dicts ⟨wm, seed, flq,
    mode⟩ — the master itself plus the draw metadata — and the matmul
    kernel quantizes tiles in-register (``kernels/ops.fxp_qdense``), so no
    quantized weight tensor exists in HBM at all. Cotangents for those
    land on "wm" (straight-through dw); ``strip_packed_grads`` extracts
    both flavors."""
    tensors = state["tensors"]
    flat_sh = None
    if shardings is not None:
        flat_sh = dict(
            (path_str(p), s) for p, s in
            jax.tree_util.tree_flatten_with_path(shardings)[0])
    sr = bool(qcfg.stochastic_rounding and key is not None)

    def _sc_for(p, leaf, fl):
        """Dequant scale 2^-FL, shaped so the scan can slice it: per-layer
        (L,)-FL leaves get (L, 1, ...); a per-TENSOR ⟨WL,FL⟩ on a scanned
        leaf (e.g. an (L, nh) d_skip, too flat for per-layer treatment)
        still needs the leading scan dim — a bare scalar would crash
        lax.scan's leading-axis slicing."""
        sc = fxp.pow2i(-fl).astype(jnp.bfloat16)
        if fl.shape:
            return sc.reshape(fl.shape + (1,) * (leaf.ndim - 1))
        if is_stacked(p) and leaf.ndim >= 2:
            return jnp.broadcast_to(sc.reshape((1,) * leaf.ndim),
                                    (leaf.shape[0],) + (1,) * (leaf.ndim - 1))
        return sc

    def visit(path, leaf):
        p = path_str(path)
        if p not in tensors:
            return leaf.astype(jnp.bfloat16)
        ts = tensors[p]
        fl = ts["fl"]
        sh = flat_sh.get(p) if flat_sh is not None else None
        if (qcfg.use_pallas and fxp.is_dense_param(p) and sh is not None
                and len(sh.device_set) > 1 and not sh.is_fully_replicated):
            # The dense Pallas kernels have no SPMD partitioning rule: a
            # >1-device-sharded dense leaf fed to them would be silently
            # REPLICATED by GSPMD (all-gathering every operand into every
            # launch). Refuse loudly instead of regressing quietly — the
            # shard_map wrapper for the dense matmuls is the open ROADMAP
            # item; until then mesh runs keep use_pallas off.
            raise ValueError(
                f"quantize_params_packed: dense leaf '{p}' is sharded over "
                "a multi-device mesh while quant.use_pallas is on — the "
                "dense kernel path (models/common.dense → fxp kernels) "
                "cannot be partitioned by GSPMD and would replicate every "
                "launch. Disable quant.use_pallas for mesh runs (ROADMAP: "
                "shard_map wrapper for the dense matmul kernels).")
        if _use_dense_prologue(qcfg, p, fl, leaf, sh):
            if fl.shape:          # stacked: per-layer folded seeds so layer
                ls = jnp.arange(fl.shape[0], dtype=jnp.int32)  # l owns its
                seed = fold_shard_seed(                        # own stream
                    _leaf_seed(key, p) if sr else jnp.int32(0), ls)
            else:
                seed = _leaf_seed(key, p) if sr else jnp.int32(0)
            wm = leaf.astype(jnp.float32)
            if sh is not None:
                wm = jax.lax.with_sharding_constraint(wm, sh)
            return {"wm": wm, "seed": seed, "flq": fl,
                    "mode": jnp.full(fl.shape, 1 if sr else 0, jnp.int32)}
        if _use_fused_prng(qcfg, key, fl, leaf, sh):
            # in-kernel PRNG: the int8 words are produced in one pass with
            # no noise operand — the packed wire payload never sees f32.
            # Sharded leaves come back from the shard_map wrapper already
            # laid out on the mesh; only wref needs the constraint.
            q8 = kops.sr_quantize_fused_int8(leaf, _leaf_seed(key, p), fl,
                                             use_pallas=True, sharding=sh)
            sc = _sc_for(p, leaf, fl)
            wref = jnp.zeros(leaf.shape, jnp.bfloat16)
            if sh is not None:
                wref = jax.lax.with_sharding_constraint(wref, sh)
            return {"q8": q8, "sc": sc, "wref": wref}
        if fl.shape:
            fl = fl.reshape(fl.shape + (1,) * (leaf.ndim - 1))
        u = None
        if qcfg.stochastic_rounding and key is not None:
            u = fxp.uniform_noise_like(_leaf_key(key, p), leaf)
            if flat_sh is not None and p in flat_sh:
                u = jax.lax.with_sharding_constraint(u, flat_sh[p])
        scale = fxp.pow2i(fl)
        x = leaf.astype(jnp.float32) * scale
        q = fxp.stochastic_round(x, u) if u is not None else jnp.round(x)
        q8 = jnp.clip(q, -128.0, 127.0).astype(jnp.int8)
        sc = _sc_for(p, leaf, ts["fl"])
        wref = jnp.zeros(leaf.shape, jnp.bfloat16)
        if flat_sh is not None and p in flat_sh:
            q8 = jax.lax.with_sharding_constraint(q8, flat_sh[p])
            wref = jax.lax.with_sharding_constraint(wref, flat_sh[p])
        return {"q8": q8, "sc": sc, "wref": wref}

    return jax.tree_util.tree_map_with_path(visit, params)


def strip_packed_grads(grads: PyTree) -> PyTree:
    """Grad tree of a packed qparams tree → plain per-param grads. A
    packed dict's cotangent lives in its "wref" (q8 carries float0); a
    quantize-prologue dict's lives in its "wm" — the straight-through
    dw = xᵀ@dy the dense kernels deposit directly on the master."""
    def is_q(g):
        return isinstance(g, dict) and frozenset(g) in (fxp.PACKED_KEYS,
                                                        fxp.QDENSE_KEYS)

    return jax.tree_util.tree_map(
        lambda g: (g["wref"] if "wref" in g else g["wm"]) if is_q(g) else g,
        grads, is_leaf=is_q)


def clamp_adapt_state(state: Dict[str, Any], max_wl) -> Dict[str, Any]:
    """AdaBits-style (1912.09666) serve-time view of the controller state:
    every tensor's WL clamped to ``max_wl``, FL reduced by the same amount
    so the integer range (max|w| representability) is preserved and only
    fractional LSBs are dropped — the same set of master weights served at
    a coarser grid. Tensors already at or below ``max_wl`` are untouched.
    Returns a NEW state dict; the trained controller state is never
    mutated, and the result has the same pytree structure/dtypes as the
    input, so quantized copies produced from different clamp levels are
    structurally identical (swap without recompiling)."""
    max_wl = jnp.int32(max_wl)
    tensors = {}
    for path, ts in state["tensors"].items():
        wl = ts["wl"]
        new_wl = jnp.minimum(wl, max_wl)
        tensors[path] = {**ts, "wl": new_wl, "fl": ts["fl"] - (wl - new_wl)}
    return {**state, "tensors": tensors}


def snapshot(state: Dict[str, Any]) -> Dict[str, Dict[str, Any]]:
    """Host-side summary {path: {wl, fl, sp, lb, res}} for logging and the
    paper's analytical performance model (eq. 6–9 need lb and r too)."""
    out = {}
    for path, ts in state["tensors"].items():
        out[path] = {
            "wl": jax.device_get(ts["wl"]),
            "fl": jax.device_get(ts["fl"]),
            "sp": jax.device_get(ts["sp"]),
            "lb": jax.device_get(ts["lb"]),
            "res": jax.device_get(ts["res"]),
        }
    return out
