"""PushDown operation (paper alg. 3): smallest ⟨WL,FL⟩ with no information loss.

The paper bins the master weights W and their quantized counterpart Ŵ into an
empirical distribution function at per-layer resolution r^l and reads the
discrete KL divergence KL(P‖Q) as "bits lost by the encoding change"; bisection
finds the smallest word length with KL ≈ 0.

TPU adaptation (DESIGN.md §3):
  * The EDF is estimated on a deterministic strided subsample (≤ cfg.edf_sample
    elements) — our tensors are 10^6–10^9 elements, the paper's ≤ 4.7M.
  * Instead of sequential bisection we evaluate the whole WL ladder in one
    vectorized pass (WL ∈ {2..16, 20, 24, 32}) and take the smallest feasible
    word — same optimum, no data-dependent control flow, vmap/scan friendly.
  * Histograms use a static r_upr-bin buffer masked down to the live r^l bins
    (dynamic shapes are impossible under jit).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import fixed_point as fxp
from repro.kernels import ops as kops

Array = jax.Array

# WL candidate ladder, ascending. Covers every width the paper can reach.
WL_LADDER = tuple(range(2, 17)) + (20, 24, 32)


def subsample(flat: Array, n: int) -> Array:
    """Deterministic strided subsample to at most n elements (static shape)."""
    size = flat.shape[0]
    if size <= n:
        return flat
    stride = size // n
    return jax.lax.slice(flat, (0,), (n * stride,), (stride,))


def _histogram(x: Array, lo: Array, hi: Array, r: Array, r_upr: int) -> Array:
    """Masked histogram: r live bins inside a static r_upr-bin buffer."""
    span = jnp.maximum(hi - lo, 1e-12)
    rf = r.astype(jnp.float32)
    idx = jnp.clip(jnp.floor((x - lo) / span * rf), 0, rf - 1).astype(jnp.int32)
    counts = jnp.zeros((r_upr,), jnp.float32).at[idx].add(1.0)
    return counts


def kl_bits(p_counts: Array, q_counts: Array) -> Array:
    """KL(P‖Q) in bits with add-one smoothing on the support union."""
    p = p_counts + 1e-6
    q = q_counts + 1e-6
    p = p / jnp.sum(p)
    q = q / jnp.sum(q)
    return jnp.sum(p * (jnp.log2(p) - jnp.log2(q)))


def kl_for_wl(w: Array, wl: Array, r: Array, r_upr: int) -> tuple[Array, Array]:
    """KL(quantized ‖ original) for one candidate word length.

    FL is range-derived (largest FL that still represents max|w|), matching
    fixed-point semantics: ⟨WL,FL⟩ must frame the value range.
    Returns (kl_bits, fl).
    """
    amax = jnp.max(jnp.abs(w))
    fl = fxp.fl_for_wl(amax, wl)
    q = fxp.quantize(w, wl, fl, u=None)  # deterministic probe
    lo, hi = jnp.min(w), jnp.max(w)
    hq = _histogram(q, lo, hi, r, r_upr)
    hw = _histogram(w, lo, hi, r, r_upr)
    return kl_bits(hq, hw), fl


def _select_wl(kls: Array, fls: Array, *, eps_kl: float,
               max_wl: int) -> tuple[Array, Array]:
    """Smallest feasible rung of the ladder given per-candidate KLs/FLs."""
    ladder = jnp.asarray(WL_LADDER, jnp.int32)
    ok = (kls < eps_kl) & (ladder <= max_wl)
    # First feasible index; fall back to the widest allowed word.
    first = jnp.argmax(ok)                       # 0 if none ok, guard below
    any_ok = jnp.any(ok)
    widest = jnp.int32(len(WL_LADDER) - 1)
    idx = jnp.where(any_ok, first, widest)
    wl_min = ladder[idx]
    fl_min = fls[idx]
    wl_min = jnp.minimum(wl_min, max_wl).astype(jnp.int32)
    fl_min = jnp.clip(fl_min, 0, wl_min - 1).astype(jnp.int32)
    return wl_min, fl_min


def push_down(w_flat: Array, r: Array, *, r_upr: int, eps_kl: float,
              max_wl: int = 32, use_pallas: bool = False
              ) -> tuple[Array, Array]:
    """Smallest ⟨WL_min, FL_min⟩ with KL < eps_kl over the WL ladder.

    w_flat: pre-subsampled 1-D f32 view of the tensor.
    Returns int32 scalars (wl_min, fl_min).

    ``use_pallas`` routes the 18 quantize+histogram probes through the fused
    EDF-ladder kernel: one pass over the data, no scatter-adds, followed by
    a tiny KL/argmin epilogue. The selected ⟨WL,FL⟩ matches this function's
    XLA reference path bit-for-bit (same bin edges, same RN quantizer).
    """
    if use_pallas:
        amax = jnp.max(jnp.abs(w_flat))
        fls = fxp.fl_for_wl(amax, jnp.asarray(WL_LADDER, jnp.int32))
        counts = kops.edf_ladder_hists(w_flat, fls, r, wl_ladder=WL_LADDER,
                                       r_upr=r_upr, use_pallas=True)
        hw = counts[0]
        kls = jax.vmap(lambda hq: kl_bits(hq, hw))(counts[1:])
        return _select_wl(kls, fls, eps_kl=eps_kl, max_wl=max_wl)

    ladder = jnp.asarray(WL_LADDER, jnp.int32)

    def probe(wl):
        return kl_for_wl(w_flat, wl, r, r_upr)

    kls, fls = jax.vmap(probe)(ladder)
    return _select_wl(kls, fls, eps_kl=eps_kl, max_wl=max_wl)
