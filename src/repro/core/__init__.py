"""AdaPT core: the paper's contribution as composable JAX modules."""
from repro.core import (controller, fixed_point, init, muppet, perf_model,
                        pushdown, pushup, sparsity)

__all__ = ["controller", "fixed_point", "init", "muppet", "perf_model",
           "pushdown", "pushup", "sparsity"]
