"""Fixed-point ⟨WL, FL⟩ quantization with stochastic rounding (paper §2.1, §3.2).

A signed fixed-point number with word length ``WL`` and fractional length
``FL`` represents values q / 2**FL with integer q in [-2**(WL-1), 2**(WL-1)-1].

Everything here is jit-friendly: WL/FL are *runtime* int32 scalars/arrays so
AdaPT precision switches never trigger recompilation. Quantized values live in
a float32 container ("simulate" mode — exactly what the paper did via QPyTorch)
or as int8 + scale ("native_int8" mode, TPU MXU path).

Stochastic rounding follows Hopkins et al. [50]: round x down with probability
1 - frac(x), up with probability frac(x). Uniform bits are supplied externally
(jax.random) so the op stays deterministic under a fixed key and matches the
Pallas kernel, which consumes identical bits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

MAX_WL = 32


def pow2i(e: Array) -> Array:
    """Exact 2^e (f32) for integer e, built from the exponent bits (clamped
    to the normal range [-126, 127]). XLA CPU lowers ``exp2`` to
    ``exp(e·ln2)``, which is off by an ulp for |e| ≳ 10 — enough to knock
    the ⟨WL,FL⟩ grid off its exact powers of two (e.g. exp2(15) =
    32767.984); every grid scale must go through this instead. The Pallas
    kernels carry their own in-kernel mirror (``sr_quantize._pow2i``)."""
    e = jnp.clip(jnp.asarray(e, jnp.int32), -126, 127)
    return jax.lax.bitcast_convert_type((e + 127) << 23, jnp.float32)


def fxp_bounds(wl: Array) -> tuple[Array, Array]:
    """(qmin, qmax) integer bounds of a signed WL-bit word (f32 container,
    exact up to WL=32: 2^31 is representable)."""
    qmax = pow2i(jnp.asarray(wl, jnp.int32) - 1) - 1.0
    return -qmax - 1.0, qmax


def stochastic_round(x: Array, u: Array) -> Array:
    """SR(x): floor(x) + (u < frac(x)). ``u`` ~ U[0,1) with x's shape."""
    f = jnp.floor(x)
    return f + (u < (x - f)).astype(x.dtype)


def quantize(w: Array, wl: Array, fl: Array, *, u: Array | None = None) -> Array:
    """Quantize to the ⟨WL,FL⟩ grid, returning values on the grid (f32 container).

    ``u`` supplies uniform [0,1) noise for stochastic rounding; ``None`` means
    round-to-nearest (used by PushDown's KL probe, which must be deterministic).
    WL/FL may be scalars or broadcastable arrays (e.g. per-scanned-layer (L,1,1)).
    """
    w = w.astype(jnp.float32)
    scale = pow2i(fl)
    qmin, qmax = fxp_bounds(wl)
    x = w * scale
    if u is None:
        q = jnp.round(x)
    else:
        q = stochastic_round(x, u.astype(jnp.float32))
    q = jnp.clip(q, qmin, qmax)
    return q / scale


def quantize_int8(w: Array, fl: Array, *, u: Array | None = None) -> tuple[Array, Array]:
    """Native path: quantize to int8 storage (WL<=8 enforced by clip) + scale 2^-FL.

    Returns (q_int8, scale) with dequant = q * scale.
    """
    w = w.astype(jnp.float32)
    scale = pow2i(fl)
    x = w * scale
    q = jnp.round(x) if u is None else stochastic_round(x, u.astype(jnp.float32))
    q = jnp.clip(q, -128.0, 127.0).astype(jnp.int8)
    return q, (1.0 / scale).astype(jnp.float32)


def required_integer_bits(w: Array, axes=None) -> Array:
    """IL bits needed to represent max|w| without overflow (excl. sign bit)."""
    amax = jnp.max(jnp.abs(w), axis=axes) if axes is not None else jnp.max(jnp.abs(w))
    amax = jnp.maximum(amax, 1e-12)
    return jnp.maximum(jnp.ceil(jnp.log2(amax + 1e-12)), 0.0).astype(jnp.int32)


def fl_for_wl(w_absmax: Array, wl: Array) -> Array:
    """Largest FL for word length WL s.t. max|w| is representable: FL = WL-1-IL."""
    il = jnp.maximum(jnp.ceil(jnp.log2(jnp.maximum(w_absmax, 1e-12))), 0.0)
    return jnp.asarray(wl, jnp.int32) - 1 - il.astype(jnp.int32)


def quantize_activation(a: Array, wl: Array, *, u: Array | None = None,
                        buff: int = 0) -> Array:
    """Dynamic-range activation quantization (paper quantizes activations too).

    FL is derived per call from the batch's abs-max so the value range always
    fits; ``buff`` extra integer headroom bits guard accumulation overflow.
    Differentiable via the straight-through estimator (round has zero
    gradient; STE passes the incoming cotangent through unchanged — the
    standard treatment [34] the paper's training relies on).
    """
    amax = jnp.max(jnp.abs(jax.lax.stop_gradient(a)))
    fl = fl_for_wl(amax, wl) - buff
    q = quantize(jax.lax.stop_gradient(a), wl, fl, u=u).astype(a.dtype)
    return a + jax.lax.stop_gradient(q - a)  # STE


def uniform_noise_like(key: Array, x: Array) -> Array:
    return jax.random.uniform(key, x.shape, jnp.float32)


# ---------------------------------------------------------------------------
# Packed int8 wire format (native_int8 §Perf): a quantized tensor travels as
# {"q8": int8, "sc": bf16 scale, "wref": bf16 zeros}. Dequant happens at the
# USE site (inside the scanned layer body, after the per-layer FSDP gather),
# so cross-chip weight movement costs 1 byte/param. Gradients route through
# the custom_vjp to "wref" — the straight-through read of paper alg. 1.

PACKED_KEYS = frozenset(("q8", "sc", "wref"))

# Quantize-PROLOGUE leaf format: the "quantized copy" of a dense-consumed
# weight is just the MASTER + ⟨seed, FL, rounding mode⟩ — the int8 words are
# drawn in-register inside the matmul prologue (kernels/fxp_matmul.fxp_qmatmul)
# and never exist in HBM. "wm" is the f32 master itself (no copy), "seed"/
# "flq"/"mode" are int32 (per-layer (L,)-vectors on stacked leaves so the
# scan slices them alongside wm). Gradients land on "wm" directly (straight-
# through dw = xᵀ@dy); controller.strip_packed_grads extracts them.
QDENSE_KEYS = frozenset(("wm", "seed", "flq", "mode"))

# Param-tree leaf names consumed by models/common.dense (2-D x@W matmuls).
# Only these are eligible for the kernel dense path — everything else that
# quantizes (embed tables, depthwise conv kernels, MoE expert einsum
# operands, d_skip) keeps the materialized packed container and is
# dequantized at its use site exactly as before.
DENSE_PARAM_NAMES = frozenset((
    "wq", "wk", "wv", "wo",            # attention projections
    "wi_gate", "wi_up",                # gated-MLP in-projections
    "in_proj", "out_proj",             # SSM / audio-frontend projections
    "head",                            # LM head
))


def is_packed(leaf) -> bool:
    return isinstance(leaf, dict) and frozenset(leaf) == PACKED_KEYS


def is_qdense(leaf) -> bool:
    return isinstance(leaf, dict) and frozenset(leaf) == QDENSE_KEYS


def is_dense_param(path: str) -> bool:
    """True when the (slash-joined) param path names a dense-layer weight
    — the leaves ``models/common.dense`` knows how to feed to the Pallas
    fxp kernels without an HBM dequant copy."""
    return path.rsplit("/", 1)[-1] in DENSE_PARAM_NAMES


@jax.custom_vjp
def dequant_packed(q8: Array, sc: Array, wref: Array) -> Array:
    del wref
    return q8.astype(jnp.bfloat16) * sc


def _dequant_fwd(q8, sc, wref):
    return dequant_packed(q8, sc, wref), sc


def _dequant_bwd(sc, g):
    import numpy as np
    return (np.zeros(g.shape, jax.dtypes.float0),
            jnp.zeros_like(sc),
            g.astype(jnp.bfloat16))


dequant_packed.defvjp(_dequant_fwd, _dequant_bwd)


def qdense_view(wm: Array, seed: Array, flq: Array, mode: Array) -> Array:
    """Materialize (in XLA) the value view of a quantize-prologue leaf:
    the dequantized ⟨8,FL⟩ words the matmul prologue draws in-register,
    regenerated from the bit-pinned portable stream (kernels/ref.py). Used
    for the regularizer terms — elementwise + scalar reductions, so XLA
    fuses it into the penalty reduction and no param-sized copy lands in
    HBM. Straight-through: the cotangent passes to ``wm`` unchanged."""
    from repro.kernels import ref as _ref

    def one(w, s, f, m):
        words = _ref.ref_qdense_words(w, s, f, m).astype(jnp.float32)
        return words * jnp.ldexp(jnp.float32(1.0), -jnp.asarray(f, jnp.int32))

    view = (jax.vmap(one)(wm, seed, flq, mode) if jnp.ndim(flq)
            else one(wm, seed, flq, mode))
    view = view.astype(wm.dtype)
    return wm + jax.lax.stop_gradient(view - wm)


def _is_quantized_dict(leaf) -> bool:
    return is_packed(leaf) or is_qdense(leaf)


def unpack_tree(tree, keep_dense: bool = False):
    """Dequantize every packed / prologue leaf in a (sub)tree; plain leaves
    pass. ``keep_dense=True`` leaves dicts whose path names a dense-layer
    weight (``is_dense_param``) INTACT — the kernel dense path consumes
    them directly (``models/common.dense``), so they must survive the
    use-site unpack that every other quantized leaf still gets.

    If the sharding rules carry '#packed_slice_specs' (path-suffix →
    NamedSharding), the int8 payload is constrained to that (TP-only) spec
    FIRST — this pins the FSDP all-gather onto the 1-byte tensor; without
    it GSPMD reshards after the dequant-multiply and the wire carries bf16
    (measured on arctic-480b; EXPERIMENTS.md §Perf)."""
    from repro import sharding as _sh
    specs = _sh.flag("#packed_slice_specs") or {}

    def visit(path, leaf):
        if not _is_quantized_dict(leaf):
            return leaf
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        if keep_dense and is_dense_param(key):
            return leaf
        if is_qdense(leaf):
            return qdense_view(leaf["wm"], leaf["seed"], leaf["flq"],
                               leaf["mode"])
        q8 = leaf["q8"]
        if specs:
            for suffix, spec in specs.items():
                if key.endswith(suffix) and \
                        len(spec.spec) == q8.ndim:
                    q8 = jax.lax.with_sharding_constraint(q8, spec)
                    break
        return dequant_packed(q8, leaf["sc"], leaf["wref"])

    return jax.tree_util.tree_map_with_path(visit, tree,
                                            is_leaf=_is_quantized_dict)


def sparsity(w: Array, axes=None, eps: float = 0.0) -> Array:
    """Fraction of non-zero elements (paper's sp^l). eps treats |w|<=eps as zero."""
    nz = (jnp.abs(w) > eps).astype(jnp.float32)
    return jnp.mean(nz, axis=axes)
