"""Mamba2 block via SSD (state-space duality, arXiv:2405.21060).

TPU adaptation (DESIGN.md §3): the SSD *chunked* form is used — the sequence
is split into chunks of length Q; within a chunk attention-like einsums hit
the MXU, across chunks a tiny `lax.scan` carries the (H, P, N) state. This is
the matmul-rich decomposition the paper derives; it maps onto TPU far better
than the recurrent selective-scan kernel Mamba1 used on GPUs.

Per DESIGN.md §4 the SSM *dynamics* parameters (a_log, dt_bias, D) and the
recurrent state stay float32 / unquantized — they pass through exponentials;
the big projection matrices (in_proj/out_proj/conv) are AdaPT-quantized.

Decode runs the O(1) recurrent form against a persistent (conv, ssm) state.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro import sharding
from repro.config import ModelConfig
from repro.models import common

Array = jax.Array


def dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    """(d_inner, num_ssm_heads, head_dim, state)."""
    d_inner = cfg.ssm_expand * cfg.d_model
    hd = cfg.ssm_head_dim
    return d_inner, d_inner // hd, hd, cfg.ssm_state


def init_layer(key: Array, cfg: ModelConfig, num_layers: int) -> Dict[str, Array]:
    d = cfg.d_model
    di, nh, hd, n = dims(cfg)
    kw = cfg.ssm_conv_width
    ks = jax.random.split(key, 4)
    L = (num_layers,) if num_layers > 0 else ()
    # in_proj packs [z (di) | x (di) | B (n) | C (n) | dt (nh)]
    return {
        "in_proj": common.init_dense(ks[0], L + (d, 2 * di + 2 * n + nh)),
        "conv_w": common.init_dense(ks[1], L + (kw, di + 2 * n)) * (kw ** 0.5),
        "out_proj": common.init_dense(ks[2], L + (di, d)),
        "a_log": jnp.zeros(L + (nh,), jnp.float32),          # A = -exp(a_log) = -1
        "dt_bias": jnp.full(L + (nh,), -1.0, jnp.float32),   # softplus(-1) ≈ 0.31
        "d_skip": jnp.ones(L + (nh,), jnp.float32),
        "gate_norm": jnp.zeros(L + (di,), jnp.float32),
        "pre_norm": jnp.zeros(L + (d,), jnp.float32),
    }


def causal_depthwise_conv(x: Array, w: Array) -> Array:
    """x: (B, S, C), w: (K, C); causal, statically unrolled (K is 4)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    s = x.shape[1]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):
        out = out + xp[:, i:i + s, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return out.astype(x.dtype)


def _split_proj(proj: Array, cfg: ModelConfig):
    di, nh, hd, n = dims(cfg)
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * n]
    dt = proj[..., di + di + 2 * n:]
    return z, xbc, dt


def ssd_chunked(x: Array, dt: Array, a_log: Array, B: Array, C: Array,
                d_skip: Array, chunk: int) -> Array:
    """Chunked SSD. x: (b,s,h,p); dt: (b,s,h); a_log/d_skip: (h,);
    B, C: (b,s,n) (single group shared across heads). Returns (b,s,h,p)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:  # zero-pad to a chunk multiple: dt=0 ⇒ pads are state no-ops
        zp = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        y, h_final = ssd_chunked(zp(x), zp(dt), a_log, zp(B), zp(C),
                                 d_skip, chunk)
        return y[:, :s], h_final
    nc = s // q
    xf = x.astype(jnp.float32)
    A = -jnp.exp(a_log.astype(jnp.float32))                   # (h,) negative
    dA = dt * A                                               # (b,s,h)

    xc = xf.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h)
    dAc = dA.reshape(b, nc, q, h)
    Bc = B.astype(jnp.float32).reshape(b, nc, q, n)
    Cc = C.astype(jnp.float32).reshape(b, nc, q, n)

    seg = jnp.cumsum(dAc, axis=2)                             # (b,nc,q,h)

    # --- intra-chunk (quadratic in q; the MXU-friendly part) ---
    rel = seg[:, :, :, None, :] - seg[:, :, None, :, :]       # (b,nc,i,j,h)
    causal = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
    L = jnp.where(causal, jnp.exp(rel), 0.0)                  # decay matrix
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)            # (b,nc,i,j)
    y_diag = jnp.einsum("bcij,bcijh,bcjh,bcjhp->bcihp",
                        scores, L, dtc, xc)

    # --- chunk boundary states ---
    last = seg[:, :, -1:, :]                                  # (b,nc,1,h)
    sdec = jnp.exp(last - seg)                                # (b,nc,q,h)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Bc, sdec * dtc, xc)
    cdec = jnp.exp(jnp.sum(dAc, axis=2))                      # (b,nc,h)

    # --- inter-chunk recurrence (tiny scan over nc) ---
    def step(hprev, inp):
        st, dec = inp
        return hprev * dec[:, :, None, None] + st, hprev
    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    xs = (jnp.moveaxis(states, 1, 0), jnp.moveaxis(cdec, 1, 0))
    h_final, h_in = jax.lax.scan(step, h0, xs)                # (nc,b,h,p,n)
    h_in = jnp.moveaxis(h_in, 0, 1)                           # (b,nc,h,p,n)

    # --- off-diagonal: y_i += exp(seg_i) C_i · H_in ---
    y_off = jnp.einsum("bcin,bchpn,bcih->bcihp", Cc, h_in, jnp.exp(seg))

    y = (y_diag + y_off).reshape(b, s, h, p)
    y = y + xf * d_skip.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), h_final


def apply(p: Dict[str, Array], x: Array, cfg: ModelConfig,
          return_state: bool = False, use_pallas: bool = False):
    """Full-sequence mamba2 block with residual. x: (B, S, D).

    ``return_state=True`` additionally returns the decode cache as of the
    last position (prefill → decode handoff)."""
    di, nh, hd, n = dims(cfg)
    h = common.rms_norm(x, p["pre_norm"], cfg.norm_eps)
    proj = common.dense(h, p["in_proj"], use_pallas=use_pallas)
    z, xbc_raw, dtraw = _split_proj(proj, cfg)
    xbc = causal_depthwise_conv(xbc_raw, p["conv_w"])
    xbc = jax.nn.silu(xbc)
    xin = xbc[..., :di]
    B = xbc[..., di:di + n]
    C = xbc[..., di + n:]
    dt = jax.nn.softplus(dtraw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    bsz, s, _ = x.shape
    y, h_final = ssd_chunked(xin.reshape(bsz, s, nh, hd), dt, p["a_log"],
                             B, C, p["d_skip"], cfg.ssm_chunk)
    y = y.reshape(bsz, s, di)
    y = common.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                        p["gate_norm"], cfg.norm_eps)
    y = sharding.shard(y, "batch", "seq", "ff")
    out = common.dense(y, p["out_proj"], use_pallas=use_pallas)
    out = sharding.shard(out, "batch", "seq", None)
    if return_state:
        kw = p["conv_w"].shape[-2]
        cache = {"conv": xbc_raw[:, s - (kw - 1):, :], "ssm": h_final}
        return x + out, cache
    return x + out


def init_cache(cfg: ModelConfig, batch: int, num_layers: int, dtype=jnp.float32):
    """Decode-time state: rolling conv inputs + recurrent SSM state."""
    di, nh, hd, n = dims(cfg)
    kw = cfg.ssm_conv_width
    L = (num_layers,) if num_layers > 0 else ()
    return {
        "conv": jnp.zeros(L + (batch, kw - 1, di + 2 * n), dtype),
        "ssm": jnp.zeros(L + (batch, nh, hd, n), jnp.float32),
    }


def apply_decode(p: Dict[str, Array], x: Array, cfg: ModelConfig,
                 cache: Dict[str, Array], use_pallas: bool = False
                 ) -> Tuple[Array, Dict[str, Array]]:
    """One-token recurrent step. x: (B, 1, D)."""
    di, nh, hd, n = dims(cfg)
    h = common.rms_norm(x, p["pre_norm"], cfg.norm_eps)
    proj = common.dense(h, p["in_proj"], use_pallas=use_pallas)
    z, xbc, dtraw = _split_proj(proj, cfg)

    conv_in = jnp.concatenate([cache["conv"], xbc], axis=1)   # (B, K, C)
    w = p["conv_w"].astype(jnp.float32)                       # (K, C)
    xbc1 = jnp.sum(conv_in.astype(jnp.float32) * w[None], axis=1, keepdims=True)
    xbc1 = jax.nn.silu(xbc1).astype(x.dtype)
    new_conv = conv_in[:, 1:, :]

    xin = xbc1[..., :di].reshape(-1, nh, hd)                  # (B,H,P)
    B_ = xbc1[:, 0, di:di + n]                                # (B,N)
    C_ = xbc1[:, 0, di + n:]
    dt = jax.nn.softplus(dtraw[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    dec = jnp.exp(dt * A)                                     # (B,H)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt, B_.astype(jnp.float32),
                     xin.astype(jnp.float32))
    ssm = cache["ssm"] * dec[:, :, None, None] + upd          # (B,H,P,N)
    y = jnp.einsum("bhpn,bn->bhp", ssm, C_.astype(jnp.float32))
    y = y + xin.astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(-1, 1, di).astype(x.dtype)
    y = common.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                        p["gate_norm"], cfg.norm_eps)
    out = common.dense(y, p["out_proj"], use_pallas=use_pallas)
    return x + out, {"conv": new_conv, "ssm": ssm}
