"""Grouped-query attention block with SWA/softcap/cross-attn and KV caching.

One set of pure functions, used three ways:
  * ``attend_full``  — training / encoding / prefill (no or fresh cache)
  * ``attend_decode``— single-token decode against a (possibly rolling) cache
  * ``cross_attend`` — queries over a static encoder memory (VLM layers)

Per-layer parameters arrive already sliced by the scan driver; the runtime
``window`` scalar makes local/global alternation (gemma2) a data choice, not
a structural one — a "global" layer simply carries window >= seq_len.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import sharding
from repro.config import ModelConfig
from repro.kernels import ops
from repro.models import common

Array = jax.Array

# XLA CPU cannot execute batched BF16×BF16→F32 dots (see models/moe.py);
# upcast there — TPU keeps the bf16 AV contraction.
_CPU_EXEC = jax.default_backend() == "cpu"


def init_layer(key: Array, cfg: ModelConfig, num_layers: int,
               cross: bool = False) -> Dict[str, Array]:
    d, h, hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    dh = cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    L = (num_layers,) if num_layers > 0 else ()
    mk = lambda k, shape: common.init_dense(k, L + shape)
    p = {
        "wq": mk(ks[0], (d, h * dh)),
        "wk": mk(ks[1], (d, hkv * dh)),
        "wv": mk(ks[2], (d, hkv * dh)),
        "wo": mk(ks[3], (h * dh, d)),
        "pre_norm": jnp.zeros(L + (d,), jnp.float32),
    }
    if cfg.use_post_norm:
        p["post_norm"] = jnp.zeros(L + (d,), jnp.float32)
    if cfg.use_qk_norm:
        p["q_norm"] = jnp.zeros(L + (dh,), jnp.float32)
        p["k_norm"] = jnp.zeros(L + (dh,), jnp.float32)
    return p


def _project_qkv(p, x, cfg: ModelConfig, positions: Optional[Array],
                 rope_on: bool = True, use_pallas: bool = False):
    B, S, _ = x.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = common.dense(x, p["wq"], use_pallas=use_pallas).reshape(B, S, h, dh)
    k = common.dense(x, p["wk"], use_pallas=use_pallas).reshape(B, S, hkv, dh)
    v = common.dense(x, p["wv"], use_pallas=use_pallas).reshape(B, S, hkv, dh)
    if cfg.use_qk_norm:
        q = common.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = common.rms_norm(k, p["k_norm"], cfg.norm_eps)
    if rope_on and positions is not None:
        q = common.rope(q, positions, cfg.rope_theta)
        k = common.rope(k, positions, cfg.rope_theta)
    q = sharding.shard(q, "batch", "q_seq", "heads", None)
    k = sharding.shard(k, "batch", "seq", "kv_heads", None)
    v = sharding.shard(v, "batch", "seq", "kv_heads", None)
    return q, k, v


def attend_full(p: Dict[str, Array], x: Array, cfg: ModelConfig,
                positions: Array, *, window: Array | int = 0,
                causal: bool = True, use_pallas: bool = False
                ) -> Tuple[Array, Tuple[Array, Array]]:
    """Self-attention over the whole sequence. Returns (out, (k, v)).

    ``window`` may be a traced scalar (per-layer from the scan); the pallas
    kernel needs a static window so the dynamic form uses the masked path.
    """
    h = common.rms_norm(x, p["pre_norm"], cfg.norm_eps)
    q, k, v = _project_qkv(p, h, cfg, positions, use_pallas=use_pallas)
    static_window = isinstance(window, int)
    if use_pallas and static_window:
        out = ops.attention(q, k, v, causal=causal, window=window,
                            softcap=cfg.attn_logit_softcap, use_pallas=True)
    else:
        out = _masked_attention(q, k, v, positions, positions, window,
                                cfg.attn_logit_softcap, causal)
    B, S = x.shape[:2]
    out = common.dense(out.reshape(B, S, -1), p["wo"], use_pallas=use_pallas)
    out = sharding.shard(out, "batch", "seq", None)
    if cfg.use_post_norm:
        out = common.rms_norm(out, p["post_norm"], cfg.norm_eps)
    return x + out, (k, v)


def _masked_attention(q, k, v, qpos, kpos, window, cap, causal):
    """einsum attention with explicit position masks.

    GQA uses the repeat-kv formulation: K/V are broadcast to the full H
    query heads BEFORE the score einsums so the contraction keeps a single
    (B, H, Sq, Skv) structure whose head axis shards over `model`. The naive
    (Hkv, rep) reshape breaks GSPMD head-sharding propagation and silently
    replicates the quadratic einsums on every chip (measured 16× the FLOPs
    on the 16-way mesh — see EXPERIMENTS.md §Perf).

    qpos: (B, Sq), kpos: (B, Skv) absolute positions; kpos = -1 marks empty
    cache slots. ``window`` may be a traced scalar (0 disables it).
    """
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)             # (B, Skv, H, D)
        v = jnp.repeat(v, rep, axis=2)
    pad_to = sharding.flag("#pad_heads_to")
    if pad_to and pad_to > H:                      # shardable-head padding
        pz = ((0, 0), (0, 0), (0, pad_to - H), (0, 0))
        q = jnp.pad(q, pz)
        k = jnp.pad(k, pz)
        v = jnp.pad(v, pz)
        q = sharding.shard(q, "batch", "q_seq", "heads", None)
    if rep > 1 or (pad_to and pad_to > H):
        # "kv_seq" is () except in split-KV decode / long-context rules,
        # where the cache sequence (not heads) carries the model axis
        k = sharding.shard(k, "batch", "kv_seq", "heads", None)
        v = sharding.shard(v, "batch", "kv_seq", "heads", None)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (1.0 / D ** 0.5)
    logits = common.softcap(logits, cap)
    logits = sharding.shard(logits, "batch", "heads", "q_seq", None)
    qp = qpos[:, :, None]                          # (B, Sq, 1)
    kp = kpos[:, None, :]                          # (B, 1, Skv)
    mask = kp >= 0                                 # (B, Sq, Skv) by broadcast
    if causal:
        mask = mask & (kp <= qp)
    w = jnp.asarray(window)
    mask = jnp.where(w > 0, mask & (kp > qp - w), mask)
    logits = jnp.where(mask[:, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    # probabilities in the input dtype for the AV contraction (what flash
    # kernels do): halves P/V traffic and the f32 dk/dv backward payloads
    av_dt = jnp.float32 if _CPU_EXEC else v.dtype
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(av_dt), v.astype(av_dt),
                     preferred_element_type=jnp.float32)
    if pad_to and pad_to > H:
        out = out[:, :, :H, :]
    return out.astype(q.dtype)


def attend_decode(p: Dict[str, Array], x: Array, cfg: ModelConfig,
                  cache_k: Array, cache_v: Array, slot_pos: Array, t: Array,
                  *, window: Array | int = 0, use_pallas: bool = False
                  ) -> Tuple[Array, Tuple[Array, Array]]:
    """One-token decode. x: (B, 1, D); cache: (B, C, Hkv, Dh); slot_pos: (C,)
    absolute positions per cache slot (-1 = empty); t: current position."""
    h = common.rms_norm(x, p["pre_norm"], cfg.norm_eps)
    B = x.shape[0]
    pos = jnp.broadcast_to(t[None, None], (B, 1))
    q, k, v = _project_qkv(p, h, cfg, pos, use_pallas=use_pallas)
    C = cache_k.shape[1]
    slot = (t % C).astype(jnp.int32)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), slot, axis=1)
    kpos = jnp.broadcast_to(slot_pos[None, :], (B, C))
    out = _masked_attention(q, cache_k, cache_v, pos, kpos, window,
                            cfg.attn_logit_softcap, causal=True)
    out = common.dense(out.reshape(B, 1, -1), p["wo"], use_pallas=use_pallas)
    if cfg.use_post_norm:
        out = common.rms_norm(out, p["post_norm"], cfg.norm_eps)
    return x + out, (cache_k, cache_v)


def cross_attend(p: Dict[str, Array], x: Array, cfg: ModelConfig,
                 memory_k: Array, memory_v: Array,
                 use_pallas: bool = False) -> Array:
    """Cross-attention over a precomputed encoder memory (VLM layers).
    memory_k/v: (B, M, Hkv, Dh) — projected once at prefill."""
    h = common.rms_norm(x, p["pre_norm"], cfg.norm_eps)
    B, S, _ = x.shape
    hq, dh = cfg.num_heads, cfg.resolved_head_dim
    q = common.dense(h, p["wq"], use_pallas=use_pallas).reshape(B, S, hq, dh)
    q = sharding.shard(q, "batch", "seq", "heads", None)
    M = memory_k.shape[1]
    kpos = jnp.broadcast_to(jnp.arange(M)[None], (B, M))
    qpos = jnp.broadcast_to(jnp.full((1,), M, jnp.int32), (B, S))
    out = _masked_attention(q, memory_k, memory_v, qpos, kpos, 0,
                            cfg.attn_logit_softcap, causal=False)
    out = common.dense(out.reshape(B, S, -1), p["wo"], use_pallas=use_pallas)
    if cfg.use_post_norm:
        out = common.rms_norm(out, p["post_norm"], cfg.norm_eps)
    return x + out


def project_memory(p: Dict[str, Array], memory: Array, cfg: ModelConfig,
                   use_pallas: bool = False) -> Tuple[Array, Array]:
    """Project encoder memory to (k, v) once (used by cross layers)."""
    B, M, _ = memory.shape
    hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    k = common.dense(memory, p["wk"], use_pallas=use_pallas
                     ).reshape(B, M, hkv, dh)
    v = common.dense(memory, p["wv"], use_pallas=use_pallas
                     ).reshape(B, M, hkv, dh)
    return k, v
