"""AlexNet (CIFAR variant) and ResNet20 — the paper's own evaluation models.

Pure-functional param dicts; every conv kernel / FC matrix is its own path so
the AdaPT controller assigns *per-layer* ⟨WL,FL⟩ exactly as in the paper
(figs. 3/4 plot these trajectories). BatchNorm scale/shift and running stats
are excluded from quantization (cfg.quant.exclude matches "norm").

Width multiplier `width` scales channel counts so CPU tests/repro runs stay
fast while the structure (depth, per-layer shapes' ratios) stays faithful.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import init as weight_init

Array = jax.Array


def conv(x: Array, w: Array, stride: int = 1, padding: str = "SAME") -> Array:
    return jax.lax.conv_general_dilated(
        x, w.astype(x.dtype), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.float32).astype(x.dtype)


def max_pool(x: Array, size: int = 2, stride: int = 2) -> Array:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, size, size, 1), (1, stride, stride, 1),
        "VALID")


def batch_norm(x: Array, p: Dict[str, Array], stats: Dict[str, Array],
               train: bool, momentum: float = 0.9, eps: float = 1e-5
               ) -> Tuple[Array, Dict[str, Array]]:
    if train:
        mean = jnp.mean(x, axis=(0, 1, 2))
        var = jnp.var(x, axis=(0, 1, 2))
        new = {"mean": momentum * stats["mean"] + (1 - momentum) * mean,
               "var": momentum * stats["var"] + (1 - momentum) * var}
    else:
        mean, var = stats["mean"], stats["var"]
        new = stats
    y = (x - mean) * jax.lax.rsqrt(var + eps) * p["norm_scale"] + p["norm_bias"]
    return y, new


def _conv_init(key, kh, kw, cin, cout, scale=1.0):
    return weight_init.tnvs(key, (kh, kw, cin, cout), scale=scale, kind="conv")


# ---------------------------------------------------------------------------
# AlexNet (CIFAR)


def init_alexnet(key: Array, num_classes: int = 10, width: float = 1.0
                 ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    w = lambda c: max(int(c * width), 8)
    ks = jax.random.split(key, 8)
    params = {
        "conv1": {"w": _conv_init(ks[0], 3, 3, 3, w(64))},
        "conv2": {"w": _conv_init(ks[1], 3, 3, w(64), w(192))},
        "conv3": {"w": _conv_init(ks[2], 3, 3, w(192), w(384))},
        "conv4": {"w": _conv_init(ks[3], 3, 3, w(384), w(256))},
        "conv5": {"w": _conv_init(ks[4], 3, 3, w(256), w(256))},
        "fc1": {"w": weight_init.tnvs(ks[5], (w(256) * 16, w(1024))),
                "b": jnp.zeros((w(1024),), jnp.float32)},
        "fc2": {"w": weight_init.tnvs(ks[6], (w(1024), w(1024))),
                "b": jnp.zeros((w(1024),), jnp.float32)},
        "fc3": {"w": weight_init.tnvs(ks[7], (w(1024), num_classes)),
                "b": jnp.zeros((num_classes,), jnp.float32)},
    }
    return params, {}


def alexnet_forward(params, stats, x: Array, train: bool = True
                    ) -> Tuple[Array, Dict]:
    """x: (B, 32, 32, 3) → logits (B, classes)."""
    h = jax.nn.relu(conv(x, params["conv1"]["w"]))
    h = max_pool(h)                                   # 16x16
    h = jax.nn.relu(conv(h, params["conv2"]["w"]))
    h = max_pool(h)                                   # 8x8
    h = jax.nn.relu(conv(h, params["conv3"]["w"]))
    h = jax.nn.relu(conv(h, params["conv4"]["w"]))
    h = jax.nn.relu(conv(h, params["conv5"]["w"]))
    h = max_pool(h)                                   # 4x4
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["fc1"]["w"] + params["fc1"]["b"])
    h = jax.nn.relu(h @ params["fc2"]["w"] + params["fc2"]["b"])
    return h @ params["fc3"]["w"] + params["fc3"]["b"], stats


# ---------------------------------------------------------------------------
# ResNet20 (CIFAR)


def init_resnet20(key: Array, num_classes: int = 10, width: float = 1.0
                  ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    w = lambda c: max(int(c * width), 4)
    chans = [w(16), w(32), w(64)]
    params: Dict[str, Any] = {}
    stats: Dict[str, Any] = {}
    keys = iter(jax.random.split(key, 64))

    def bn(c):
        return ({"norm_scale": jnp.ones((c,), jnp.float32),
                 "norm_bias": jnp.zeros((c,), jnp.float32)},
                {"mean": jnp.zeros((c,), jnp.float32),
                 "var": jnp.ones((c,), jnp.float32)})

    p, s = bn(chans[0])
    params["stem"] = {"w": _conv_init(next(keys), 3, 3, 3, chans[0]), **p}
    stats["stem"] = s
    cin = chans[0]
    for stage, cout in enumerate(chans):
        for block in range(3):
            name = f"s{stage}b{block}"
            stride = 2 if (stage > 0 and block == 0) else 1
            p1, s1 = bn(cout)
            p2, s2 = bn(cout)
            bp = {"conv1": {"w": _conv_init(next(keys), 3, 3, cin, cout), **p1},
                  "conv2": {"w": _conv_init(next(keys), 3, 3, cout, cout), **p2}}
            bs = {"conv1": s1, "conv2": s2}
            if stride != 1 or cin != cout:
                pd, sd = bn(cout)
                bp["down"] = {"w": _conv_init(next(keys), 1, 1, cin, cout), **pd}
                bs["down"] = sd
            params[name] = bp
            stats[name] = bs
            cin = cout
    params["fc"] = {"w": weight_init.tnvs(next(keys), (chans[2], num_classes)),
                    "b": jnp.zeros((num_classes,), jnp.float32)}
    return params, stats


def _basic_block(bp, bs, x, stride, train):
    h, n1 = batch_norm(conv(x, bp["conv1"]["w"], stride), bp["conv1"],
                       bs["conv1"], train)
    h = jax.nn.relu(h)
    h, n2 = batch_norm(conv(h, bp["conv2"]["w"]), bp["conv2"], bs["conv2"], train)
    new = {"conv1": n1, "conv2": n2}
    if "down" in bp:
        x, nd = batch_norm(conv(x, bp["down"]["w"], stride), bp["down"],
                           bs["down"], train)
        new["down"] = nd
    return jax.nn.relu(x + h), new


def resnet20_forward(params, stats, x: Array, train: bool = True
                     ) -> Tuple[Array, Dict]:
    """x: (B, 32, 32, 3) → logits (B, classes)."""
    new_stats: Dict[str, Any] = {}
    h, new_stats["stem"] = batch_norm(conv(x, params["stem"]["w"]),
                                      params["stem"], stats["stem"], train)
    h = jax.nn.relu(h)
    for stage in range(3):
        for block in range(3):
            name = f"s{stage}b{block}"
            stride = 2 if (stage > 0 and block == 0) else 1
            h, new_stats[name] = _basic_block(params[name], stats[name], h,
                                              stride, train)
    h = jnp.mean(h, axis=(1, 2))
    return h @ params["fc"]["w"] + params["fc"]["b"], new_stats


MODELS = {
    "alexnet": (init_alexnet, alexnet_forward),
    "resnet20": (init_resnet20, resnet20_forward),
}


def ce_loss(logits: Array, labels: Array) -> Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def accuracy(logits: Array, labels: Array) -> Array:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


def layer_madds(params, input_hw: int = 32) -> Dict[str, float]:
    """Per-tensor MAdds for one forward pass (feeds the paper's perf model).

    Convs: kh·kw·cin·cout·H_out·W_out; FC/linear: in·out. Spatial sizes track
    the fixed CIFAR topology (pools/strides known from the forward fns).
    """
    out: Dict[str, float] = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        keys = [str(getattr(k, "key", k)) for k in path]
        if keys[-1] != "w" or leaf.ndim < 2:
            continue
        name = "/".join(keys)
        if leaf.ndim == 4:
            kh, kw, cin, cout = leaf.shape
            # crude but faithful spatial bookkeeping: assume 32→16→8→4 halvings
            # by position in the net (documented approximation of eq. 8 inputs)
            hw = input_hw
            if "conv2" in name or "s1" in name:
                hw = input_hw // 2
            if any(t in name for t in ("conv3", "conv4", "conv5", "s2")):
                hw = input_hw // 4
            out[name] = float(kh * kw * cin * cout * hw * hw)
        else:
            out[name] = float(leaf.shape[-2] * leaf.shape[-1])
    return out
