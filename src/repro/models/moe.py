"""Mixture-of-Experts FFN: top-k routing with capacity-bounded einsum
dispatch (mesh-TF style) — fully shardable: expert dim over the `model`
axis (EP) when divisible, else ff-dim TP inside each expert.

mixtral-8x22b: 8 experts top-2; arctic-480b: 128 experts top-2 *plus* a
parallel dense residual FFN (its "dense-MoE hybrid").

The router stays float32 and is excluded from AdaPT quantization
(DESIGN.md §4): top-k indices are discontinuous in the logits, so routing
flips under quantization noise destabilize training for no byte savings
(router is ~d_model×E ≈ 10⁻⁵ of parameters).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro import sharding
from repro.config import ModelConfig
from repro.models import common

Array = jax.Array

# XLA's CPU thunk runtime cannot execute batched BF16×BF16→F32 dots
# ("DotThunk: unsupported element type"); TPU MXU handles them natively.
# On CPU we upcast the expert einsum operands — numerics-identical, and the
# dry-run (which only compiles) is unaffected on its bytes accounting for
# TPU targets except a documented ≤2× pessimism on MoE weight bytes.
_CPU_EXEC = jax.default_backend() == "cpu"


def _edot(spec: str, a: Array, b: Array) -> Array:
    if _CPU_EXEC:
        a = a.astype(jnp.float32)
        b = b.astype(jnp.float32)
    return jnp.einsum(spec, a, b, preferred_element_type=jnp.float32)


def init_layer(key: Array, cfg: ModelConfig, num_layers: int) -> Dict[str, Array]:
    d = cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    e = cfg.num_experts
    ks = jax.random.split(key, 5)
    L = (num_layers,) if num_layers > 0 else ()
    p = {
        "router": common.init_dense(ks[0], L + (d, e)),
        "we_gate": common.init_dense(ks[1], L + (e, d, f)),
        "we_up": common.init_dense(ks[2], L + (e, d, f)),
        "we_down": common.init_dense(ks[3], L + (e, f, d)),
        "pre_norm": jnp.zeros(L + (d,), jnp.float32),
    }
    if cfg.dense_residual_d_ff:
        from repro.models import mlp
        p["dense"] = mlp.init_layer(ks[4], cfg, num_layers,
                                    d_ff=cfg.dense_residual_d_ff)
    return p


def apply(p: Dict[str, Array], x: Array, cfg: ModelConfig,
          dropless: bool = False, use_pallas: bool = False) -> Array:
    """x: (B, S, D) -> (B, S, D) with residual.

    GShard-style **group-limited** capacity dispatch: tokens are split into
    g groups aligned with the data-parallel shards (g = mesh dp size, read
    from the sharding rules at trace time; 1 on a single device). Each group
    ranks its own tokens and owns cap_g = cf·k·T_g/E expert slots, so the
    dispatch scatter, the (g, E, cap_g, D) expert buffer and the expert
    einsums all keep the group dim sharded over data — a *global* cumsum/
    buffer forces GSPMD to replicate the entire MoE across the data axis
    (measured 16× FLOPs on the 16-way mesh; EXPERIMENTS.md §Perf).

    Tokens past an expert's per-group capacity are dropped (standard) —
    except with ``dropless=True`` (decode: T tiny, g=1, cap=T).
    """
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    h = common.rms_norm(x, p["pre_norm"], cfg.norm_eps)
    T = B * S
    g = 1 if dropless else sharding.axis_size("batch")
    if T % g or T < g:
        g = 1
    Tg = T // g
    cap = Tg if dropless else max(int(cfg.capacity_factor * k * Tg / E), 1)
    cap = min(cap, Tg * k)

    tokens = h.reshape(g, Tg, D)
    tokens = sharding.shard(tokens, "batch", None, None)
    logits = jnp.einsum("gtd,de->gte", tokens.astype(jnp.float32),
                        p["router"].astype(jnp.float32))         # (g, Tg, E)
    weights, chosen = jax.lax.top_k(logits, k)                   # (g, Tg, k)
    weights = jax.nn.softmax(weights, axis=-1)

    flat_e = chosen.reshape(g, Tg * k)                           # (g, Tg·k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)          # (g, Tg·k, E)
    pos = jnp.cumsum(onehot, axis=1) - onehot                    # rank in group
    pos_sel = jnp.take_along_axis(pos, flat_e[..., None], axis=2)[..., 0]
    keep = pos_sel < cap
    dest = jnp.where(keep, flat_e * cap + pos_sel, E * cap)      # drop slot

    tok_rep = jnp.repeat(tokens, k, axis=1)                      # (g, Tg·k, D)
    xin = jnp.zeros((g, E * cap + 1, D), x.dtype)
    xin = jax.vmap(lambda xz, d, t: xz.at[d].add(t))(xin, dest, tok_rep)
    xin = xin[:, :E * cap].reshape(g, E, cap, D)
    xin = sharding.shard(xin, "batch", "experts", None, None)

    gate = _edot("gecd,edf->gecf", xin, p["we_gate"].astype(x.dtype))
    up = _edot("gecd,edf->gecf", xin, p["we_up"].astype(x.dtype))
    act = (common.act_fn(gate, cfg.act_fn) * up).astype(x.dtype)
    act = sharding.shard(act, "batch", "experts", None, "ff")
    eout = _edot("gecf,efd->gecd", act,
                 p["we_down"].astype(x.dtype)).astype(x.dtype)
    eout = sharding.shard(eout, "batch", "experts", None, None)

    eflat = jnp.concatenate(
        [eout.reshape(g, E * cap, D), jnp.zeros((g, 1, D), x.dtype)], axis=1)
    gathered = jax.vmap(lambda ef, d: ef[d])(eflat, dest)        # (g, Tg·k, D)
    gathered = gathered.reshape(g, Tg, k, D).astype(jnp.float32)
    out = jnp.sum(gathered * weights[..., None], axis=2)
    out = out.reshape(B, S, D).astype(x.dtype)
    out = sharding.shard(out, "batch", "seq", None)

    if "dense" in p:  # arctic: parallel dense residual FFN
        from repro.models import mlp
        out = out + mlp.apply(p["dense"], h, cfg, residual=False,
                              use_pallas=use_pallas)
    return x + out


def aux_load_balance_loss(p: Dict[str, Array], x: Array, cfg: ModelConfig) -> Array:
    """Switch-style load-balancing auxiliary (mean over layers handled by
    caller). Kept separate so the dry-run path can skip it."""
    B, S, D = x.shape
    tokens = x.reshape(B * S, D).astype(jnp.float32)
    logits = jnp.dot(tokens, p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    _, chosen = jax.lax.top_k(logits, cfg.experts_per_token)
    frac = jnp.mean(jax.nn.one_hot(chosen[:, 0], cfg.num_experts), axis=0)
    return cfg.num_experts * jnp.sum(frac * jnp.mean(probs, axis=0))
