"""Shared model building blocks (pure functions over param dicts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import sharding
from repro.core import fixed_point as fxp
from repro.core import init as weight_init

Array = jax.Array


def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def softcap(logits: Array, cap: float) -> Array:
    if cap <= 0.0:
        return logits
    return cap * jnp.tanh(logits / cap)


def act_fn(x: Array, kind: str) -> Array:
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.silu(x)


def dense(x: Array, w, *, out_logical: str | None = None,
          use_pallas: bool = False) -> Array:
    """x @ w with f32 accumulation; annotates the contraction output.

    ``w`` is either a plain weight array or a QUANTIZED-LEAF dict the
    controller emitted (container_dtype="int8_packed"):

    * packed ⟨q8, sc, wref⟩ — materialized int8 words. Under
      ``use_pallas`` they stream straight into the fxp Pallas kernels
      (``kernels/ops.fxp_dense``: fwd + dx on int8 tiles, dequant
      in-register, straight-through dw onto wref) — the weights are never
      dequantized into HBM. Without it, the XLA dequant-then-dot path.
    * prologue ⟨wm, seed, flq, mode⟩ — no words at all: the kernel
      quantizes master tiles in VMEM en route to the MXU
      (``kernels/ops.fxp_qdense``). Pallas-only by construction (the
      controller emits it only under use_pallas + dense_prologue).

    With the '#tp_reduce_bf16' rules flag, the plain dot's output dtype is
    bf16: the MXU still accumulates in f32 internally, but row-parallel
    partial sums cross the ICI in bf16 — half the TP all-reduce bytes for
    a ~2^-8 relative rounding on a 16-way sum (§Perf lever). The flag
    applies to the PLAIN-array path only: the kernel paths accumulate in
    f32 VMEM scratch and emit x.dtype. NOTE the kernel paths are
    single-device/replicated constructs — pallas_call has no SPMD
    partitioning rule. The controller keeps explicitly-sharded leaves off
    the PROLOGUE format (controller._use_dense_prologue), but a sharded
    MATERIALIZED packed leaf handed here under use_pallas would still be
    replicated by GSPMD; shard_map-wrapping the dense kernels is the open
    ROADMAP item, and no shipped config enables use_pallas on a mesh."""
    if isinstance(w, dict):
        y = _dense_quantized(x, w, use_pallas)
    else:
        pref = (jnp.bfloat16 if sharding.flag("#tp_reduce_bf16")
                and x.dtype == jnp.bfloat16 else jnp.float32)
        y = jnp.dot(x, w.astype(x.dtype), preferred_element_type=pref)
        y = y.astype(x.dtype)
    if out_logical and x.ndim == 3:
        y = sharding.shard(y, "batch", "seq", out_logical)
    return y


def _dense_quantized(x: Array, w: dict, use_pallas: bool) -> Array:
    """Dense over a quantized-leaf dict; x may be (..., K) — the kernels
    take 2-D, so leading dims are flattened into M."""
    from repro.kernels import ops  # local: models stay importable sans ops

    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if fxp.is_qdense(w):
        # scan-sliced per-layer metadata arrives as size-1 arrays
        seed, flq, mode = (jnp.reshape(w[k], ()) for k in
                          ("seed", "flq", "mode"))
        y2 = ops.fxp_qdense(x2, w["wm"], seed, flq, mode,
                            use_pallas=use_pallas, out_dtype=x.dtype)
    elif fxp.is_packed(w):
        if use_pallas:
            y2 = ops.fxp_dense(x2, w["q8"], jnp.reshape(w["sc"], ()),
                               w["wref"], use_pallas=True, out_dtype=x.dtype)
        else:
            # Defensive only: the model's own call sites unpack packed
            # dicts upstream when use_pallas is off, so this branch serves
            # direct callers handing dense() a packed leaf — it is the
            # EXACT legacy path (unpack_tree's dequant + the plain dot),
            # not a reimplementation of ops.fxp_dense's f32 fallback.
            wd = fxp.dequant_packed(w["q8"], w["sc"], w["wref"])
            y2 = jnp.dot(x2, wd.astype(x.dtype),
                         preferred_element_type=jnp.float32).astype(x.dtype)
    else:
        raise TypeError(f"dense: unrecognized weight dict keys {set(w)}")
    return y2.reshape(lead + (y2.shape[-1],))


def rope(x: Array, positions: Array, theta: float) -> Array:
    """Rotary embedding. x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    half = d // 2
    freq = jnp.exp(-jnp.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq       # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                             # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:2 * half]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    if 2 * half < d:  # odd head dim: pass the tail through
        rot = jnp.concatenate([rot, x[..., 2 * half:]], axis=-1)
    return rot.astype(x.dtype)


def quantize_act(x: Array, wl: Array | None, enabled: bool) -> Array:
    """Activation fixed-point quantization at the layer's word length
    (dynamic-range FL, nearest rounding — see DESIGN.md §8)."""
    if not enabled or wl is None:
        return x
    return fxp.quantize_activation(x, wl)


def embed_lookup(table: Array, ids: Array, scale_by_dim: bool = False) -> Array:
    out = jnp.take(table, ids, axis=0)
    if scale_by_dim:
        out = out * jnp.asarray(table.shape[-1] ** 0.5, out.dtype)
    return sharding.shard(out, "batch", "seq", None)


def init_dense(key: Array, shape, scale: float = 1.0) -> Array:
    return weight_init.tnvs(key, shape, scale=scale, kind="linear")


def init_embed(key: Array, vocab: int, d: int, scale: float = 1.0) -> Array:
    return weight_init.tnvs(key, (vocab, d), scale=scale, kind="embed")
