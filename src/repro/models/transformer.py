"""Unified LM stack over all assigned architectures.

Layer heterogeneity (gemma2 local/global alternation, zamba2 mamba+shared-attn
interleave, llama-vision cross-attn every k layers) is handled with a
**periodic plan**: the per-layer descriptor list is always periodic for these
architectures, so we stack parameters as (num_periods, ...) per *slot* within
the period and `lax.scan` over periods. Each scan step statically unrolls the
period's few slots — windows, layer kinds and FFN kinds are static per slot
(so e.g. gemma2's local slots get a *static* window, Pallas-kernel friendly),
while AdaPT's per-layer ⟨WL,FL⟩ remain runtime arrays indexed by period.

Params layout (all stacked leaves carry the leading num_periods dim):

    {"embed": (V, D)?,                 # absent for audio (frontend stub)
     "in_proj": (F, D)?,               # audio: frame-embedding projection
     "blocks": {"s{i}_attn"|"s{i}_mamba"|"s{i}_cross": {...},
                "s{i}_mlp"|"s{i}_moe": {...}},
     "shared": {...}?,                 # zamba2: one unstacked attn+mlp block
     "final_norm": (D,),
     "head": (D, V)?}                  # absent when tie_embeddings

The AdaPT controller sees "blocks/..." paths as per-layer stacked (leading
dim = num_periods) and everything else as per-tensor — matching the paper's
per-layer precision at period granularity.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import sharding
from repro.config import ModelConfig
from repro.core import fixed_point as fxp
from repro.models import attention, common, mlp, moe, ssm

Array = jax.Array

# Quantized leaves (fxp.PACKED_KEYS / QDENSE_KEYS dicts) are dequantized at
# the use site: INSIDE the scan body for per-layer weights (so the FSDP
# gather moves int8, not bf16/f32) and at entry for embed/head. Under
# use_pallas, DENSE-consumed leaves (fixed_point.DENSE_PARAM_NAMES) are NOT
# dequantized at all — they ride through intact and common.dense feeds them
# straight to the fxp Pallas kernels (int8 tiles into the MXU, dequant
# in-register; quantize-prologue leaves never materialize words anywhere).
_unpack = fxp.unpack_tree


# ---------------------------------------------------------------------------
# Plan


@dataclass(frozen=True)
class Slot:
    kind: str          # attn | mamba | cross
    window: int        # 0 = full; >0 = sliding window (static!)
    ffn: str           # mlp | moe | none
    shared: bool = False  # weights shared across periods (zamba2 attn blocks)

    @property
    def name(self) -> str:
        return self.kind


def _layer_descriptors(cfg: ModelConfig) -> list:
    """Fully expanded per-layer slot list (length num_layers)."""
    ffn_default = ("moe" if cfg.num_experts else
                   ("mlp" if cfg.d_ff else "none"))
    out = []
    attn_idx = 0
    for i in range(cfg.num_layers):
        if cfg.cross_attn_every and (i + 1) % cfg.cross_attn_every == 0:
            kind = "cross"
        else:
            kind = cfg.layer_pattern[i % len(cfg.layer_pattern)]
        window = 0
        ffn = ffn_default
        shared = False
        if kind == "attn":
            pat = cfg.attn_pattern[attn_idx % len(cfg.attn_pattern)]
            window = cfg.window_size if pat == "local" else 0
            attn_idx += 1
            shared = cfg.shared_attn_weights
        elif kind == "mamba":
            ffn = "none"
        out.append(Slot(kind, window, ffn, shared))
    return out


def build_plan(cfg: ModelConfig) -> Tuple[Tuple[Slot, ...], int]:
    """Smallest periodic plan: (slots_per_period, num_periods)."""
    layers = _layer_descriptors(cfg)
    L = len(layers)
    for p in range(1, L + 1):
        if L % p:
            continue
        if all(layers[i] == layers[i % p] for i in range(L)):
            return tuple(layers[:p]), L // p
    return tuple(layers), 1


def slot_key(i: int, slot: Slot) -> str:
    return f"s{i}_{slot.kind}"


def ffn_key(i: int, slot: Slot) -> str:
    return f"s{i}_{slot.ffn}"


# ---------------------------------------------------------------------------
# Init


def init_params(key: Array, cfg: ModelConfig) -> Dict[str, Any]:
    plan, np_ = build_plan(cfg)
    keys = jax.random.split(key, 4 + 2 * len(plan))
    params: Dict[str, Any] = {"blocks": {}}
    ki = 0

    def nk():
        nonlocal ki
        ki += 1
        return keys[ki - 1]

    if not cfg.is_encoder:
        params["embed"] = common.init_embed(nk(), cfg.vocab_size, cfg.d_model)
    else:
        # audio stub frontend: frames arrive at d_model already (input_specs);
        # a learned projection keeps the path trainable end-to-end.
        params["in_proj"] = common.init_dense(nk(), (cfg.d_model, cfg.d_model))

    shared_attn = None
    for i, slot in enumerate(plan):
        if slot.kind in ("attn", "cross"):
            if slot.shared:
                if shared_attn is None:
                    shared_attn = attention.init_layer(nk(), cfg, 0)
                    params.setdefault("shared", {})["attn"] = shared_attn
                    if slot.ffn == "mlp":
                        params["shared"]["mlp"] = mlp.init_layer(nk(), cfg, 0)
            else:
                params["blocks"][slot_key(i, slot)] = attention.init_layer(
                    nk(), cfg, np_, cross=(slot.kind == "cross"))
        elif slot.kind == "mamba":
            params["blocks"][slot_key(i, slot)] = ssm.init_layer(nk(), cfg, np_)
        if slot.ffn == "mlp" and not slot.shared:
            params["blocks"][ffn_key(i, slot)] = mlp.init_layer(nk(), cfg, np_)
        elif slot.ffn == "moe":
            params["blocks"][ffn_key(i, slot)] = moe.init_layer(nk(), cfg, np_)

    params["final_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if not cfg.tie_embeddings:
        params["head"] = common.init_dense(
            nk(), (cfg.d_model, cfg.vocab_size or 1))
    return params


# ---------------------------------------------------------------------------
# Forward (training / prefill)


def _slot_params(blocks, plan, i, slot, shared):
    if slot.shared:
        return shared["attn"]
    return blocks[slot_key(i, slot)]


def _apply_ffn(pffn, x, cfg, slot: Slot, shared, dropless: bool = False,
               use_pallas: bool = False):
    if slot.ffn == "none":
        return x
    if slot.shared:
        return (mlp.apply(shared["mlp"], x, cfg, use_pallas=use_pallas)
                if "mlp" in (shared or {}) else x)
    if slot.ffn == "moe":
        return moe.apply(pffn, x, cfg, dropless=dropless,
                         use_pallas=use_pallas)
    return mlp.apply(pffn, x, cfg, use_pallas=use_pallas)


def _maybe_qact(x, act_wl, name, enabled):
    if not enabled or act_wl is None or name not in act_wl:
        return x
    return common.quantize_act(x, act_wl[name], True)


def forward(params: Dict[str, Any], cfg: ModelConfig, *,
            tokens: Optional[Array] = None,
            embeds: Optional[Array] = None,
            memory: Optional[Array] = None,
            act_wl: Optional[Dict[str, Array]] = None,
            use_pallas: bool = False, remat: str = "none") -> Array:
    """Full-sequence forward → logits (B, S, V).

    tokens: (B, S) int32 for LM archs; embeds: (B, S, D) for the audio stub;
    memory: (B, M, D) precomputed image-patch embeddings for cross slots.
    remat: "none" | "full" | "selective" — activation checkpointing of the
    per-period scan body (training at 4k×256 needs it to fit HBM).
    """
    plan, np_ = build_plan(cfg)
    params = {**params, **_unpack({k: v for k, v in params.items()
                                   if k != "blocks"}, keep_dense=use_pallas)}
    shared = params.get("shared")

    if tokens is not None:
        x = common.embed_lookup(params["embed"], tokens,
                                scale_by_dim=cfg.scale_embed)
        x = x.astype(jnp.bfloat16)
    else:
        x = common.dense(embeds.astype(jnp.bfloat16), params["in_proj"],
                         use_pallas=use_pallas)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    causal = not cfg.is_encoder

    # period-stacked xs for the scan (block params + per-period act WLs)
    xs = (params["blocks"], act_wl if act_wl is not None else {})

    def body(x, xs_slice):
        pslice, awl = xs_slice
        pslice = _unpack(pslice, keep_dense=use_pallas)
        for i, slot in enumerate(plan):
            if slot.kind == "mamba":
                x = ssm.apply(pslice[slot_key(i, slot)], x, cfg,
                              use_pallas=use_pallas)
            elif slot.kind == "cross":
                p = _slot_params(pslice, plan, i, slot, shared)
                mem_k, mem_v = attention.project_memory(
                    p, memory, cfg, use_pallas=use_pallas)
                x = attention.cross_attend(p, x, cfg, mem_k, mem_v,
                                           use_pallas=use_pallas)
            else:
                p = _slot_params(pslice, plan, i, slot, shared)
                x, _ = attention.attend_full(
                    p, x, cfg, positions, window=slot.window, causal=causal,
                    use_pallas=use_pallas)
            if slot.ffn != "none":
                pffn = None if slot.shared else pslice[ffn_key(i, slot)]
                x = _apply_ffn(pffn, x, cfg, slot, shared,
                               use_pallas=use_pallas)
            x = _maybe_qact(x, awl, slot_key(i, slot), act_wl is not None)
        return x, None

    if remat == "full":
        body = jax.checkpoint(body)
    elif remat == "selective":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    x, _ = jax.lax.scan(body, x, xs)

    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("head")
    if head is None:
        logits = common.dense(x, params["embed"].T)
    else:
        logits = common.dense(x, head, out_logical="vocab",
                              use_pallas=use_pallas)
    logits = common.softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
    return sharding.shard(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# Loss


def lm_loss(logits: Array, tokens: Array, *, shift: bool = True) -> Array:
    """Causal LM loss (shifted) or framewise CE (shift=False, encoder)."""
    if shift:
        logits = logits[:, :-1]
        targets = tokens[:, 1:]
    else:
        targets = tokens
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Decode (single new token against per-slot caches)


def cache_len(slot: Slot, context: int) -> int:
    return min(slot.window, context) if slot.window else context


def init_caches(cfg: ModelConfig, batch: int, context: int,
                dtype=jnp.bfloat16) -> Dict[str, Any]:
    plan, np_ = build_plan(cfg)
    hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    caches: Dict[str, Any] = {}
    for i, slot in enumerate(plan):
        key = slot_key(i, slot)
        if slot.kind == "attn":
            C = cache_len(slot, context)
            caches[key] = {
                "k": jnp.zeros((np_, batch, C, hkv, dh), dtype),
                "v": jnp.zeros((np_, batch, C, hkv, dh), dtype),
            }
        elif slot.kind == "mamba":
            caches[key] = ssm.init_cache(cfg, batch, np_, dtype=dtype)
        elif slot.kind == "cross":
            M = cfg.num_image_tokens
            caches[key] = {
                "k": jnp.zeros((np_, batch, M, hkv, dh), dtype),
                "v": jnp.zeros((np_, batch, M, hkv, dh), dtype),
            }
    return caches


def _slot_positions(C: int, t: Array) -> Array:
    """Absolute position held by each rolling-cache slot at time t (-1 empty)."""
    idx = jnp.arange(C, dtype=jnp.int32)
    p = t.astype(jnp.int32) - ((t.astype(jnp.int32) - idx) % C)
    return jnp.where(p >= 0, p, -1)


def decode_step(params: Dict[str, Any], cfg: ModelConfig, token: Array,
                caches: Dict[str, Any], t: Array, *,
                act_wl: Optional[Dict[str, Array]] = None,
                use_pallas: bool = False
                ) -> Tuple[Array, Dict[str, Any]]:
    """token: (B,) int32; t: () int32 current absolute position.
    Returns (logits (B, V), new caches)."""
    plan, np_ = build_plan(cfg)
    params = {**params, **_unpack({k: v for k, v in params.items()
                                   if k != "blocks"}, keep_dense=use_pallas)}
    shared = params.get("shared")
    x = common.embed_lookup(params["embed"], token[:, None],
                            scale_by_dim=cfg.scale_embed).astype(jnp.bfloat16)

    def body(x, xs_slice):
        pslice, cslice, awl = xs_slice
        pslice = _unpack(pslice, keep_dense=use_pallas)
        new_c = {}
        for i, slot in enumerate(plan):
            key = slot_key(i, slot)
            if slot.kind == "mamba":
                x, nc = ssm.apply_decode(pslice[key], x, cfg, cslice[key],
                                         use_pallas=use_pallas)
                new_c[key] = nc
            elif slot.kind == "cross":
                p = _slot_params(pslice, plan, i, slot, shared)
                x = attention.cross_attend(p, x, cfg, cslice[key]["k"],
                                           cslice[key]["v"],
                                           use_pallas=use_pallas)
                new_c[key] = cslice[key]
            else:
                p = _slot_params(pslice, plan, i, slot, shared)
                C = cslice[key]["k"].shape[1]
                spos = _slot_positions(C, t)
                x, (ck, cv) = attention.attend_decode(
                    p, x, cfg, cslice[key]["k"], cslice[key]["v"], spos, t,
                    window=slot.window, use_pallas=use_pallas)
                new_c[key] = {"k": ck, "v": cv}
            if slot.ffn != "none":
                pffn = None if slot.shared else pslice[ffn_key(i, slot)]
                x = _apply_ffn(pffn, x, cfg, slot, shared, dropless=True,
                               use_pallas=use_pallas)
            x = _maybe_qact(x, awl, key, act_wl is not None)
        return x, new_c

    x, new_caches = jax.lax.scan(
        body, x, (params["blocks"], caches,
                  act_wl if act_wl is not None else {}))

    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("head")
    logits = common.dense(x, params["embed"].T if head is None else head,
                          use_pallas=use_pallas)
    logits = common.softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
    return logits[:, 0], new_caches


# ---------------------------------------------------------------------------
# Prefill (forward + cache collection → decode handoff)


def _roll_into_cache(k: Array, C: int) -> Array:
    """Scatter the last C positions of k (B,S,H,D) into rolling-cache layout
    (slot = position % C), matching attend_decode's write pattern."""
    S = k.shape[1]
    take = k[:, S - C:]
    idx = (jnp.arange(S - C, S, dtype=jnp.int32)) % C
    out = jnp.zeros_like(take)
    return out.at[:, idx].set(take)


def prefill(params: Dict[str, Any], cfg: ModelConfig, tokens: Array, *,
            memory: Optional[Array] = None,
            act_wl: Optional[Dict[str, Array]] = None,
            use_pallas: bool = False,
            cache_dtype=jnp.bfloat16) -> Tuple[Array, Dict[str, Any]]:
    """Process the prompt, returning (last-position logits (B,V), caches)."""
    plan, np_ = build_plan(cfg)
    params = {**params, **_unpack({k: v for k, v in params.items()
                                   if k != "blocks"}, keep_dense=use_pallas)}
    shared = params.get("shared")
    x = common.embed_lookup(params["embed"], tokens,
                            scale_by_dim=cfg.scale_embed).astype(jnp.bfloat16)
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, xs_slice):
        pslice, awl = xs_slice
        pslice = _unpack(pslice, keep_dense=use_pallas)
        caches = {}
        for i, slot in enumerate(plan):
            key = slot_key(i, slot)
            if slot.kind == "mamba":
                x, st = ssm.apply(pslice[key], x, cfg, return_state=True,
                                  use_pallas=use_pallas)
                caches[key] = jax.tree.map(
                    lambda a: a.astype(cache_dtype)
                    if a.dtype != jnp.float32 else a, st)
            elif slot.kind == "cross":
                p = _slot_params(pslice, plan, i, slot, shared)
                mk, mv = attention.project_memory(p, memory, cfg,
                                                  use_pallas=use_pallas)
                x = attention.cross_attend(p, x, cfg, mk, mv,
                                           use_pallas=use_pallas)
                caches[key] = {"k": mk.astype(cache_dtype),
                               "v": mv.astype(cache_dtype)}
            else:
                p = _slot_params(pslice, plan, i, slot, shared)
                x, (k, v) = attention.attend_full(
                    p, x, cfg, positions, window=slot.window,
                    use_pallas=use_pallas)
                C = cache_len(slot, S)
                caches[key] = {"k": _roll_into_cache(k, C).astype(cache_dtype),
                               "v": _roll_into_cache(v, C).astype(cache_dtype)}
            if slot.ffn != "none":
                pffn = None if slot.shared else pslice[ffn_key(i, slot)]
                x = _apply_ffn(pffn, x, cfg, slot, shared,
                               use_pallas=use_pallas)
            x = _maybe_qact(x, awl, key, act_wl is not None)
        return x, caches

    x, caches = jax.lax.scan(
        body, x, (params["blocks"], act_wl if act_wl is not None else {}))
    x = common.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    head = params.get("head")
    logits = common.dense(x, params["embed"].T if head is None else head,
                          use_pallas=use_pallas)
    logits = common.softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
    return logits[:, 0], caches


# ---------------------------------------------------------------------------
# AdaPT integration helpers


def act_wl_from_state(adapt_state: Dict[str, Any]) -> Dict[str, Array]:
    """Per-slot activation word length = the slot out-projection's WL
    (paper: activations are quantized at the layer's precision)."""
    out = {}
    for path, ts in adapt_state["tensors"].items():
        parts = path.split("/")
        if len(parts) == 3 and parts[0] == "blocks" and parts[2] in (
                "wo", "out_proj"):
            out[parts[1]] = ts["wl"]
    return out


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
