"""Gated MLP (SwiGLU / GeGLU) block."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro import sharding
from repro.config import ModelConfig
from repro.models import common

Array = jax.Array


def init_layer(key: Array, cfg: ModelConfig, num_layers: int,
               d_ff: int | None = None) -> Dict[str, Array]:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(key, 3)
    L = (num_layers,) if num_layers > 0 else ()
    return {
        "wi_gate": common.init_dense(ks[0], L + (d, f)),
        "wi_up": common.init_dense(ks[1], L + (d, f)),
        "wo": common.init_dense(ks[2], L + (f, d)),
        "pre_norm": jnp.zeros(L + (d,), jnp.float32),
    }


def apply(p: Dict[str, Array], x: Array, cfg: ModelConfig,
          residual: bool = True, use_pallas: bool = False) -> Array:
    h = common.rms_norm(x, p["pre_norm"], cfg.norm_eps)
    gate = common.dense(h, p["wi_gate"], out_logical="ff",
                        use_pallas=use_pallas)
    up = common.dense(h, p["wi_up"], out_logical="ff", use_pallas=use_pallas)
    out = common.dense(common.act_fn(gate, cfg.act_fn) * up, p["wo"],
                       use_pallas=use_pallas)
    out = sharding.shard(out, "batch", "seq", None)
    if "post_norm" in p:
        out = common.rms_norm(out, p["post_norm"], cfg.norm_eps)
    return x + out if residual else out
