"""mamba2-780m [ssm]: 48L d_model=1536 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060; unverified]."""
from repro.config import Config, ModelConfig


def config() -> Config:
    return Config(arch="mamba2-780m", model=ModelConfig(
        name="mamba2-780m", family="ssm", num_layers=48, d_model=1536,
        num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=50280,
        layer_pattern=("mamba",), ssm_state=128, ssm_head_dim=64,
        ssm_expand=2, ssm_chunk=256))


def smoke() -> Config:
    return Config(arch="mamba2-780m", model=ModelConfig(
        name="mamba2-780m-smoke", family="ssm", num_layers=4, d_model=64,
        num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=256,
        layer_pattern=("mamba",), ssm_state=16, ssm_head_dim=16,
        ssm_expand=2, ssm_chunk=8))
