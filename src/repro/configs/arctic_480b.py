"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 + parallel dense residual FFN (dense-MoE hybrid)
[hf:Snowflake/snowflake-arctic-base; hf]."""
from repro.config import Config, ModelConfig


def config() -> Config:
    return Config(arch="arctic-480b", model=ModelConfig(
        name="arctic-480b", family="moe", num_layers=35, d_model=7168,
        num_heads=56, num_kv_heads=8, d_ff=4864, vocab_size=32000,
        num_experts=128, experts_per_token=2, dense_residual_d_ff=4864))


def smoke() -> Config:
    return Config(arch="arctic-480b", model=ModelConfig(
        name="arctic-480b-smoke", family="moe", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=96, vocab_size=256,
        num_experts=8, experts_per_token=2, dense_residual_d_ff=96))
