"""Tiny dense config for unit tests and the quickstart example."""
from repro.config import Config, ModelConfig, TrainConfig


def config() -> Config:
    return Config(arch="tiny", model=ModelConfig(
        name="tiny", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=256),
        train=TrainConfig(seq_len=64, global_batch=8, steps=10))


def smoke() -> Config:
    return config()
