"""ResNet20 (CIFAR) — the paper's own evaluation model (tabs. 1–6)."""
import dataclasses

from repro.config import Config, ModelConfig, QuantConfig, TrainConfig


def config() -> Config:
    return Config(arch="resnet20", model=ModelConfig(
        name="resnet20", family="cnn", vocab_size=10),
        quant=QuantConfig(buff=8),
        train=TrainConfig(seq_len=0, global_batch=512, steps=1000))


def smoke() -> Config:
    c = config()
    return dataclasses.replace(
        c, model=dataclasses.replace(c.model, name="resnet20-smoke"),
        train=dataclasses.replace(c.train, global_batch=16, steps=4))
