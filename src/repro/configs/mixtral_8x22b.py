"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, sliding-window attention
[arXiv:2401.04088; hf]."""
from repro.config import Config, ModelConfig


def config() -> Config:
    return Config(arch="mixtral-8x22b", model=ModelConfig(
        name="mixtral-8x22b", family="moe", num_layers=56, d_model=6144,
        num_heads=48, num_kv_heads=8, d_ff=16384, vocab_size=32768,
        num_experts=8, experts_per_token=2,
        attn_pattern=("local",), window_size=4096))


def smoke() -> Config:
    return Config(arch="mixtral-8x22b", model=ModelConfig(
        name="mixtral-8x22b-smoke", family="moe", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
        num_experts=4, experts_per_token=2,
        attn_pattern=("local",), window_size=8))
