"""AlexNet (CIFAR variant) — the paper's own evaluation model (tabs. 1–6)."""
import dataclasses

from repro.config import Config, ModelConfig, QuantConfig, TrainConfig


def config() -> Config:
    return Config(arch="alexnet", model=ModelConfig(
        name="alexnet", family="cnn", vocab_size=10),
        quant=QuantConfig(buff=4),
        train=TrainConfig(seq_len=0, global_batch=512, steps=1000))


def smoke() -> Config:
    c = config()
    return dataclasses.replace(
        c, model=dataclasses.replace(c.model, name="alexnet-smoke"),
        train=dataclasses.replace(c.train, global_batch=16, steps=4))
