"""gemma2-2b [dense]: 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000
— local+global alternating attention, logit softcaps [arXiv:2408.00118; hf].

head_dim is 256 (not d_model/H); embeddings are tied and scaled by sqrt(d);
local window 4096; attn softcap 50, final softcap 30; post-norms.
"""
from repro.config import Config, ModelConfig


def config() -> Config:
    return Config(arch="gemma2-2b", model=ModelConfig(
        name="gemma2-2b", family="dense", num_layers=26, d_model=2304,
        num_heads=8, num_kv_heads=4, head_dim=256, d_ff=9216,
        vocab_size=256000, attn_pattern=("local", "global"), window_size=4096,
        attn_logit_softcap=50.0, final_logit_softcap=30.0,
        tie_embeddings=True, scale_embed=True, use_post_norm=True,
        act_fn="gelu"))


def smoke() -> Config:
    return Config(arch="gemma2-2b", model=ModelConfig(
        name="gemma2-2b-smoke", family="dense", num_layers=4, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        attn_pattern=("local", "global"), window_size=8,
        attn_logit_softcap=50.0, final_logit_softcap=30.0,
        tie_embeddings=True, scale_embed=True, use_post_norm=True,
        act_fn="gelu"))
