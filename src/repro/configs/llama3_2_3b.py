"""llama3.2-3b [dense]: 28L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=128256 — small llama3 [hf:meta-llama/Llama-3.2-1B; unverified]."""
from repro.config import Config, ModelConfig


def config() -> Config:
    return Config(arch="llama3.2-3b", model=ModelConfig(
        name="llama3.2-3b", family="dense", num_layers=28, d_model=3072,
        num_heads=24, num_kv_heads=8, d_ff=8192, vocab_size=128256,
        rope_theta=500000.0))


def smoke() -> Config:
    return Config(arch="llama3.2-3b", model=ModelConfig(
        name="llama3.2-3b-smoke", family="dense", num_layers=2, d_model=48,
        num_heads=6, num_kv_heads=2, d_ff=96, vocab_size=128,
        rope_theta=500000.0))
