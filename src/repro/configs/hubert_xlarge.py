"""hubert-xlarge [audio]: 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504
— encoder-only, wav2vec2-style backbone [arXiv:2106.07447; unverified].

Encoder-only: no decode step exists, so decode_32k / long_500k shapes are
skipped (DESIGN.md §4). The CNN feature extractor is a STUB: input_specs()
provides precomputed frame embeddings (B, S, d_model).
"""
from repro.config import Config, ModelConfig


def config() -> Config:
    return Config(arch="hubert-xlarge", model=ModelConfig(
        name="hubert-xlarge", family="audio", num_layers=48, d_model=1280,
        num_heads=16, num_kv_heads=16, d_ff=5120, vocab_size=504,
        is_encoder=True, act_fn="gelu"))


def smoke() -> Config:
    return Config(arch="hubert-xlarge", model=ModelConfig(
        name="hubert-xlarge-smoke", family="audio", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=32,
        is_encoder=True, act_fn="gelu"))
