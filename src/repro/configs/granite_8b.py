"""granite-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152
— llama-arch, code [arXiv:2405.04324; hf]."""
from repro.config import Config, ModelConfig


def config() -> Config:
    return Config(arch="granite-8b", model=ModelConfig(
        name="granite-8b", family="dense", num_layers=36, d_model=4096,
        num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=49152,
        rope_theta=10000.0))


def smoke() -> Config:
    return Config(arch="granite-8b", model=ModelConfig(
        name="granite-8b-smoke", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256))
