"""zamba2-7b [hybrid]: 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 blocks + shared attention blocks
[arXiv:2411.15242; unverified].

Plan: period (mamba, mamba, attn) × 27; the attn(+MLP) block weights are
*shared* across all 27 periods (zamba2's signature trick).
"""
from repro.config import Config, ModelConfig


def config() -> Config:
    return Config(arch="zamba2-7b", model=ModelConfig(
        name="zamba2-7b", family="hybrid", num_layers=81, d_model=3584,
        num_heads=32, num_kv_heads=32, d_ff=14336, vocab_size=32000,
        layer_pattern=("mamba", "mamba", "attn"), shared_attn_weights=True,
        ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256))


def smoke() -> Config:
    return Config(arch="zamba2-7b", model=ModelConfig(
        name="zamba2-7b-smoke", family="hybrid", num_layers=6, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256,
        layer_pattern=("mamba", "mamba", "attn"), shared_attn_weights=True,
        ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=8))
