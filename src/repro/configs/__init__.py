"""Architecture registry: one module per assigned arch (+ the paper's own
CNNs + a tiny test config). Each module exposes ``config()`` (the exact
published dims) and ``smoke()`` (a reduced same-family config for CPU tests).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.config import Config

# arch id -> module name
_MODULES = {
    "granite-8b": "granite_8b",
    "gemma2-2b": "gemma2_2b",
    "llama3.2-3b": "llama3_2_3b",
    "smollm-360m": "smollm_360m",
    "zamba2-7b": "zamba2_7b",
    "mixtral-8x22b": "mixtral_8x22b",
    "arctic-480b": "arctic_480b",
    "llama-3.2-vision-11b": "llama3_2_vision_11b",
    "hubert-xlarge": "hubert_xlarge",
    "mamba2-780m": "mamba2_780m",
    "alexnet": "alexnet",
    "resnet20": "resnet20",
    "tiny": "tiny",
}


def _load(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


# Production-mesh training defaults for the LM family: full-scan remat +
# 8-way gradient accumulation keep live activations ≈ (batch/accum)·seq·d
# per chip (without them every 4k×256 cell blows past 16 GB HBM — see
# DESIGN.md §3 and EXPERIMENTS.md §Dry-run). arctic-480b additionally
# accumulates grads in bf16: its f32 master+grads alone are ~15 GB/chip.
_LM_TRAIN = {"remat": "full", "accum_steps": 8}
_ARCH_TRAIN = {
    "arctic-480b": {**_LM_TRAIN, "accum_dtype": "bfloat16"},
}


def get_config(arch: str) -> Config:
    import dataclasses
    cfg = _load(arch).config()
    if cfg.model.family != "cnn" and arch != "tiny":
        kw = _ARCH_TRAIN.get(arch, _LM_TRAIN)
        cfg = dataclasses.replace(
            cfg, train=dataclasses.replace(cfg.train, **kw))
    return cfg


def get_smoke_config(arch: str) -> Config:
    return _load(arch).smoke()


def list_archs() -> List[str]:
    return sorted(_MODULES)


def assigned_archs() -> List[str]:
    """The 10 assigned LM-family architectures (excludes paper CNNs/tiny)."""
    return [a for a in _MODULES if a not in ("alexnet", "resnet20", "tiny")]
