"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — cross-attn image layers every 5th layer
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].

The vision frontend is a STUB per the task spec: input_specs() provides
precomputed patch embeddings (B, num_image_tokens, d_model) consumed by the
cross-attention slots.
"""
from repro.config import Config, ModelConfig


def config() -> Config:
    return Config(arch="llama-3.2-vision-11b", model=ModelConfig(
        name="llama-3.2-vision-11b", family="vlm", num_layers=40,
        d_model=4096, num_heads=32, num_kv_heads=8, d_ff=14336,
        vocab_size=128256, cross_attn_every=5, num_image_tokens=1024,
        rope_theta=500000.0))


def smoke() -> Config:
    return Config(arch="llama-3.2-vision-11b", model=ModelConfig(
        name="llama-3.2-vision-11b-smoke", family="vlm", num_layers=4,
        d_model=64, num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
        cross_attn_every=2, num_image_tokens=16))
