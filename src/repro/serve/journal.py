"""Durable append-only request journal for the continuous batcher.

The batcher is in-memory per replica; its fault-tolerance story is that
serving state is RECONSTRUCTIBLE from the request log. This module is
that log: one JSONL line per event, appended and flushed at submit and
at every terminal transition, so a replica that dies mid-flight can be
replaced by a fresh batcher that re-admits exactly the requests that
never reached a terminal status (plus any explicitly ``evicted`` ones —
evicted means "terminal on this replica, re-admit elsewhere").

Events::

    {"ev": "submit",   "rid": 3, "prompt": [...], "max_new_tokens": 8,
     "temperature": 0.0, "eos_id": null, "deadline": null,
     "submit_time": 12.5}
    {"ev": "terminal", "rid": 3, "status": "ok", "reason": "",
     "output": [...]}

Replay is torn-write tolerant: a truncated or garbage final line (the
crash happened mid-append) is skipped, never fatal. The last event per
rid wins, so re-submitting a replayed request appends a fresh submit
line and replay stays idempotent across repeated crashes.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List


class RequestJournal:
    def __init__(self, path: str):
        self.path = path
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._f = open(path, "a")

    def record_submit(self, req) -> None:
        self._append({
            "ev": "submit",
            "rid": req.rid,
            "prompt": list(req.prompt),
            "max_new_tokens": req.max_new_tokens,
            "temperature": req.temperature,
            "eos_id": req.eos_id,
            "deadline": req.deadline,
            "submit_time": req.submit_time,
        })

    def record_terminal(self, req) -> None:
        self._append({
            "ev": "terminal",
            "rid": req.rid,
            "status": str(req.status.value),
            "reason": req.reason,
            "output": list(req.output),
        })

    def _append(self, obj: Dict[str, Any]) -> None:
        self._f.write(json.dumps(obj) + "\n")
        self._f.flush()
        os.fsync(self._f.fileno())

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    # -- replay --------------------------------------------------------------

    @staticmethod
    def unfinished(path: str) -> List[Dict[str, Any]]:
        """Parse the journal and return the submit records (in submission
        order) of every request whose LAST event is not a terminal status
        — plus those whose last status is ``evicted`` (terminal locally,
        meant for re-admission on another replica). Corrupt/truncated
        lines are skipped."""
        if not os.path.exists(path):
            return []
        submits: Dict[int, Dict[str, Any]] = {}
        order: List[int] = []
        finished: Dict[int, str] = {}
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    ev = json.loads(line)
                except json.JSONDecodeError:
                    continue                      # torn write: skip the tail
                rid = ev.get("rid")
                if ev.get("ev") == "submit" and rid is not None:
                    if rid not in submits:
                        order.append(rid)
                    submits[rid] = ev
                    finished.pop(rid, None)       # re-submitted after replay
                elif ev.get("ev") == "terminal" and rid is not None:
                    finished[rid] = ev.get("status", "")
        out = []
        for rid in order:
            status = finished.get(rid)
            if status is None or status == "evicted":
                out.append(submits[rid])
        return out
