"""Deterministic fault injection for the serving decode path.

Production decode fails in two characteristic ways: *corrupt output* (a
flipped HBM bit or a bad cache row yields NaN/inf logits for one
sequence) and *transient errors* (a preempted device, a flaky
interconnect — the decode call raises and a retry succeeds). The
batcher's handling of both is a robustness contract, so the injector
makes them reproducible: faults fire on an explicit per-step schedule
(or a seeded random one), never on wall clock, so a failing test replays
bit-for-bit.

The batcher calls ``before_decode(step, attempt)`` immediately before
each decode attempt (may raise ``TransientDecodeError``) and
``corrupt_logits(step, logits)`` on the decode's output (may poison
per-slot rows with NaN/inf). Scheduled transient errors fire ONCE per
step by default — the batcher's in-step retry then succeeds, which is
what "transient" means; ``persistent_errors=True`` makes every attempt
at a scheduled step raise, exercising the retry-budget exhaustion path.
"""
from __future__ import annotations

import math
import random
from typing import Dict, Iterable, List, Mapping, Sequence, Tuple


class TransientDecodeError(RuntimeError):
    """A decode attempt failed in a (presumed) recoverable way."""


class FaultInjector:
    """Deterministic per-step fault schedule.

    ``nan_steps``: {scheduler step: slot indices} whose logits rows are
    overwritten with ``corrupt_value`` after the decode at that step.
    ``error_steps``: scheduler steps whose decode attempt raises
    ``TransientDecodeError`` (once per step unless ``persistent_errors``).
    ``fired`` records every injection actually delivered, in order."""

    def __init__(self, nan_steps: Mapping[int, Sequence[int]] | None = None,
                 error_steps: Iterable[int] | None = None, *,
                 corrupt_value: float = math.nan,
                 persistent_errors: bool = False):
        self.nan_steps: Dict[int, Tuple[int, ...]] = {
            int(s): tuple(slots) for s, slots in (nan_steps or {}).items()}
        self._error_steps = set(int(s) for s in (error_steps or ()))
        self.corrupt_value = corrupt_value
        self.persistent_errors = persistent_errors
        self.fired: List[tuple] = []

    @classmethod
    def seeded(cls, seed: int, steps: int, slots: int, *,
               nan_rate: float = 0.0, error_rate: float = 0.0,
               corrupt_value: float = math.nan,
               persistent_errors: bool = False) -> "FaultInjector":
        """Random-but-reproducible schedule over ``steps`` scheduler steps:
        each step independently corrupts one random slot with probability
        ``nan_rate`` and raises with probability ``error_rate``. Same seed
        → same schedule, on any platform (stdlib ``random``)."""
        rng = random.Random(seed)
        nan_steps: Dict[int, Tuple[int, ...]] = {}
        error_steps = set()
        for s in range(steps):
            if nan_rate and rng.random() < nan_rate:
                nan_steps[s] = (rng.randrange(slots),)
            if error_rate and rng.random() < error_rate:
                error_steps.add(s)
        return cls(nan_steps, error_steps, corrupt_value=corrupt_value,
                   persistent_errors=persistent_errors)

    def before_decode(self, step: int, attempt: int = 0) -> None:
        """Raise if a transient error is scheduled for ``step``. One-shot
        per step (the retry models the transient clearing) unless
        ``persistent_errors``."""
        if step in self._error_steps:
            if not self.persistent_errors:
                self._error_steps.discard(step)
            self.fired.append(("error", step, attempt))
            raise TransientDecodeError(
                f"injected transient decode error at step {step} "
                f"(attempt {attempt})")

    def corrupt_logits(self, step: int, logits):
        """Overwrite the scheduled slots' logits rows with
        ``corrupt_value`` (NaN by default; pass ``math.inf`` for the
        overflow flavor). Non-scheduled steps pass through untouched."""
        slots = self.nan_steps.get(step)
        if not slots:
            return logits
        import jax.numpy as jnp
        idx = jnp.asarray(slots, jnp.int32)
        self.fired.append(("nan", step, slots))
        return logits.at[idx].set(self.corrupt_value)
