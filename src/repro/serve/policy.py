"""Load→precision policy for AdaBits-style degraded serving.

AdaBits (1912.09666) shows one set of trained weights can serve multiple
bit-widths; the AdaPT controller already owns per-layer ⟨WL,FL⟩ state, so
overload can be answered by *degrading precision* instead of shedding
load. This module maps observed queue pressure to a word length from a
fixed ladder; the batcher pre-materializes one quantized word set per
level (``serve/engine.quantize_serving_levels``) and swaps the active
tree between decode steps — same pytree structure, so the jitted decode
never recompiles.

The controller is a plain hysteresis state machine, deliberately free of
wall-clock reads: it is driven once per scheduler step with (queue depth,
p95 queue wait) and requires ``patience`` CONSECUTIVE pressure
observations to step down one level and ``patience`` consecutive drain
observations to step up one level. Mixed observations reset both
counters. Levels are walked one step at a time in both directions — no
level skipping — so the WL trace under a load profile is deterministic
and testable.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass
class PrecisionPolicy:
    """Hysteresis map (queue depth, p95 queue wait) → serving word length.

    ``levels`` is the WL ladder, strictly descending, ``levels[0]`` = full
    precision. Pressure = depth ≥ ``high_watermark`` OR (when
    ``p95_high_ms`` > 0) p95 queue wait ≥ ``p95_high_ms``; drain = depth ≤
    ``low_watermark`` and no latency pressure. ``patience`` consecutive
    pressure observations step one level DOWN; ``patience`` consecutive
    drain observations step one level UP."""

    levels: Tuple[int, ...] = (8, 6, 4)
    high_watermark: int = 8
    low_watermark: int = 1
    p95_high_ms: float = 0.0
    patience: int = 2

    def __post_init__(self):
        if not self.levels:
            raise ValueError("PrecisionPolicy: empty level ladder")
        if list(self.levels) != sorted(set(self.levels), reverse=True):
            raise ValueError(
                f"PrecisionPolicy: levels must be strictly descending, got "
                f"{self.levels}")
        if self.low_watermark >= self.high_watermark:
            raise ValueError(
                "PrecisionPolicy: low_watermark must be < high_watermark "
                f"({self.low_watermark} >= {self.high_watermark})")
        if self.patience < 1:
            raise ValueError("PrecisionPolicy: patience must be >= 1")
        self._idx = 0
        self._down = 0
        self._up = 0

    @classmethod
    def from_config(cls, scfg) -> "PrecisionPolicy":
        """Build from a ``config.ServeConfig``."""
        return cls(levels=tuple(scfg.degrade_levels),
                   high_watermark=scfg.degrade_high_watermark,
                   low_watermark=scfg.degrade_low_watermark,
                   p95_high_ms=scfg.degrade_p95_ms,
                   patience=scfg.degrade_patience)

    @property
    def wl(self) -> int:
        return self.levels[self._idx]

    def observe(self, queue_depth: int, p95_wait_ms: float = 0.0) -> int:
        """Feed one per-step observation; returns the active WL after it."""
        latency_pressure = (self.p95_high_ms > 0.0
                            and p95_wait_ms >= self.p95_high_ms)
        pressure = queue_depth >= self.high_watermark or latency_pressure
        drained = queue_depth <= self.low_watermark and not latency_pressure
        if pressure:
            self._up = 0
            self._down += 1
            if self._down >= self.patience and \
                    self._idx < len(self.levels) - 1:
                self._idx += 1
                self._down = 0
        elif drained:
            self._down = 0
            self._up += 1
            if self._up >= self.patience and self._idx > 0:
                self._idx -= 1
                self._up = 0
        else:                       # between watermarks: hold, reset both
            self._down = 0
            self._up = 0
        return self.levels[self._idx]
