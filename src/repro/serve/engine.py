"""Serving engine for AdaPT-trained (fully quantized, sparsified) models.

The paper's headline inference claim (tab. 6: SU 1.52–3.56, SZ 0.36–0.60)
rests on the trained network *staying* quantized after training — unlike
MuPPET, which emits float32. This engine consumes the AdaPT controller's
final ⟨WL,FL⟩ map, quantizes the weights ONCE at load, and serves from the
quantized copy; the float32 master is never shipped.

Two jitted entry points (also the dry-run's serve-shape targets):
  * ``prefill_step``  — prompt → (first logits, KV/SSM caches)
  * ``decode_step``   — one token for every sequence in the batch

``Engine`` wraps them with greedy/temperature sampling and batched request
padding. Fault tolerance: the engine is stateless between calls (caches are
caller-held), so a failed replica is replaced by re-prefilling on a healthy
one — no checkpoint needed for serving.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import Config
from repro.core import controller
from repro.models import transformer

Array = jax.Array


def quantize_for_serving(params, adapt_state, qcfg, max_wl=None):
    """One-shot weight quantization at the final ⟨WL,FL⟩ (deterministic —
    nearest rounding; SR is a training-time device). ``max_wl`` optionally
    clamps every tensor's word length first (AdaBits-style degraded
    serving; see ``controller.clamp_adapt_state``).

    With ``container_dtype="int8_packed"`` the engine serves from the SAME
    packed tree format the train step uses — dense layers feed int8 words
    straight to the fxp Pallas kernels via models/common.dense, so train
    and serve share one code path and one word draw (RTN is bit-identical
    across dispatches). The quantize-PROLOGUE format is deliberately
    disabled here regardless of ``quant.dense_prologue``: weights are
    static at serve time, so re-drawing words in every matmul prologue
    would hold the f32 master (4× the weight bytes) and re-quantize per
    decode step for zero benefit — serving always materializes the words
    once, at load."""
    if not adapt_state or not adapt_state.get("tensors"):
        return params
    if max_wl is not None:
        adapt_state = controller.clamp_adapt_state(adapt_state, max_wl)
    if qcfg.container_dtype == "int8_packed":
        import dataclasses
        qcfg = dataclasses.replace(qcfg, dense_prologue=False)
        return controller.quantize_params_packed(params, adapt_state, qcfg,
                                                 key=None)
    return controller.quantize_params(params, adapt_state, qcfg, key=None)


def quantize_serving_levels(params, adapt_state, qcfg, levels):
    """Pre-materialize one quantized word set per serving word length
    (AdaBits: one set of trained weights served at multiple bit-widths).
    Returns {wl: qparams} for ``levels`` (descending WL, levels[0] = full
    precision). Every level is produced by the same deterministic
    requantization with the controller state WL-clamped, so all trees are
    STRUCTURALLY IDENTICAL (same treedef, leaf shapes, and dtypes) — the
    batcher swaps the active tree between decode steps and the jitted
    decode never recompiles. Structural identity is asserted here, at
    load, rather than discovered as a recompile at peak load.

    Without controller state there is nothing to requantize: the single
    passthrough tree is returned under levels[0]."""
    levels = tuple(levels)
    if not levels:
        raise ValueError("quantize_serving_levels: empty level ladder")
    if not adapt_state or not adapt_state.get("tensors"):
        return {levels[0]: quantize_for_serving(params, adapt_state, qcfg)}
    out = {wl: quantize_for_serving(params, adapt_state, qcfg, max_wl=wl)
           for wl in levels}
    ref_struct = jax.tree_util.tree_structure(out[levels[0]])
    ref_leaves = jax.tree_util.tree_leaves(out[levels[0]])
    for wl in levels[1:]:
        if jax.tree_util.tree_structure(out[wl]) != ref_struct:
            raise AssertionError(
                f"serving level WL={wl} produced a different pytree "
                "structure than the full-precision level — swapping it in "
                "would recompile the decode step")
        for a, b in zip(ref_leaves, jax.tree_util.tree_leaves(out[wl])):
            if a.shape != b.shape or a.dtype != b.dtype:
                raise AssertionError(
                    f"serving level WL={wl}: leaf {a.shape}/{a.dtype} vs "
                    f"{b.shape}/{b.dtype} — precision swap would recompile")
    return out


def make_prefill(cfg: Config):
    m = cfg.model

    def prefill_step(qparams, tokens, memory=None):
        return transformer.prefill(qparams, m, tokens, memory=memory,
                                   use_pallas=cfg.quant.use_pallas)

    return prefill_step


def make_decode(cfg: Config):
    m = cfg.model

    def decode_step(qparams, token, caches, t):
        return transformer.decode_step(qparams, m, token, caches, t,
                                       use_pallas=cfg.quant.use_pallas)

    return decode_step


def sample(logits: Array, key: Array, temperature: float = 0.0) -> Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1
                                  ).astype(jnp.int32)


class Engine:
    """Minimal batched serving engine over the quantized model."""

    def __init__(self, cfg: Config, params, adapt_state: Optional[dict] = None):
        self.cfg = cfg
        self.qparams = quantize_for_serving(params, adapt_state or {},
                                            cfg.quant)
        self._prefill = jax.jit(make_prefill(cfg))
        self._decode = jax.jit(make_decode(cfg), donate_argnums=2)

    def generate(self, tokens: Array, max_new_tokens: int, *,
                 memory: Optional[Array] = None, temperature: float = 0.0,
                 seed: int = 0) -> Tuple[Array, Array]:
        """tokens: (B, S) prompt batch (right-aligned, same length).
        Returns (generated (B, max_new), last logits)."""
        B, S = tokens.shape
        context = S + max_new_tokens
        caches = transformer.init_caches(self.cfg.model, B, context)
        logits, pref_caches = self._prefill(self.qparams, tokens, memory)
        caches = _merge_prefill_caches(caches, pref_caches, S)
        key = jax.random.PRNGKey(seed)
        out = []
        tok = sample(logits, key, temperature)
        for i in range(max_new_tokens):
            out.append(tok)
            if i == max_new_tokens - 1:
                break
            t = jnp.int32(S + i)
            logits, caches = self._decode(self.qparams, tok, caches, t)
            tok = sample(logits, jax.random.fold_in(key, i), temperature)
        return jnp.stack(out, axis=1), logits


def _merge_prefill_caches(full: Dict[str, Any], pref: Dict[str, Any],
                          prompt_len: int) -> Dict[str, Any]:
    """Embed prefill caches (sized to the prompt) into the generation-sized
    cache buffers. Positions keep their slot = pos %% C invariant because the
    full cache length C' >= prompt length and slots are re-derived from t."""
    merged = {}
    for key, slot_cache in full.items():
        p = pref[key]
        if "ssm" in slot_cache:                       # mamba: shapes equal
            merged[key] = jax.tree.map(lambda a, b: b.astype(a.dtype),
                                       slot_cache, p)
            continue
        dst_k, src_k = slot_cache["k"], p["k"]
        C_dst, C_src = dst_k.shape[2], src_k.shape[2]
        if C_dst == C_src:
            merged[key] = {"k": src_k.astype(dst_k.dtype),
                           "v": p["v"].astype(dst_k.dtype)}
            continue
        # re-layout: source slot s held position pos = roll-layout of the
        # prompt; rewrite into destination slot pos % C_dst.
        pos = jnp.arange(prompt_len - C_src, prompt_len, dtype=jnp.int32)
        src_slot = pos % C_src
        dst_slot = pos % C_dst
        k = dst_k.at[:, :, dst_slot].set(src_k[:, :, src_slot].astype(dst_k.dtype))
        v = slot_cache["v"].at[:, :, dst_slot].set(
            p["v"][:, :, src_slot].astype(dst_k.dtype))
        merged[key] = {"k": k, "v": v}
    return merged
