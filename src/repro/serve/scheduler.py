"""Continuous-batching request scheduler over the serve engine.

Production serving rarely sees aligned batches: requests arrive with
different prompt lengths and different generation budgets. The scheduler
maintains a fixed pool of `slots` (the jitted decode step has a static
batch dimension), admits queued requests into free slots between decode
steps, and retires sequences as they hit their token budget or EOS —
classic continuous batching (Orca/vLLM style) expressed with a *static*
batch so nothing ever recompiles.

Per-slot state lives in the shared caches at distinct batch rows; admission
"prefills" a new prompt by running single-row decode steps over the prompt
tokens (CPU-friendly and shape-stable; on TPU a dedicated row-prefill with
the full prefill kernel would amortize this — noted in DESIGN.md).

Fault tolerance: the scheduler is in-memory per replica; on replica loss,
un-finished requests are simply re-admitted elsewhere (serving state is
reconstructible from the request log — no checkpoints needed).
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.config import Config
from repro.core import controller
from repro.models import transformer
from repro.serve.engine import quantize_for_serving, sample

Array = jax.Array


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    temperature: float = 0.0
    eos_id: Optional[int] = None
    # filled by the scheduler
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class _Slot:
    request: Optional[Request] = None
    pos: int = 0                 # absolute position of the next token
    pending: List[int] = dataclasses.field(default_factory=list)

    @property
    def free(self) -> bool:
        return self.request is None


class ContinuousBatcher:
    def __init__(self, cfg: Config, params, adapt_state=None, *,
                 slots: int = 4, max_context: int = 256, seed: int = 0):
        self.cfg = cfg
        self.m = cfg.model
        self.slots = [_Slot() for _ in range(slots)]
        self.max_context = max_context
        self.qparams = quantize_for_serving(params, adapt_state or {},
                                            cfg.quant)
        self.queue: collections.deque = collections.deque()
        self._rid = itertools.count()
        self._key = jax.random.PRNGKey(seed)
        self._step_i = 0
        self.caches = transformer.init_caches(self.m, slots, max_context)
        # one decode step over the whole slot pool; per-slot positions
        self._decode = jax.jit(self._decode_fn)

    def _decode_fn(self, qparams, tokens, caches, positions):
        """tokens: (S,) int32 per slot; positions: (S,) int32 per slot.
        Uses per-slot positions by vmapping the single-row decode."""
        m = self.m

        def one(tok, pos, cache_row):
            cache1 = jax.tree.map(lambda a: a[:, None], cache_row)
            logits, new1 = transformer.decode_step(
                qparams, m, tok[None], cache1, pos,
                use_pallas=self.cfg.quant.use_pallas)
            return logits[0], jax.tree.map(lambda a: a[:, 0], new1)

        # move the batch axis (dim 1 of (NP, B, ...)) to the front for vmap
        swapped = jax.tree.map(lambda a: jnp.moveaxis(a, 1, 0), caches)
        logits, new_sw = jax.vmap(one, in_axes=(0, 0, 0))(tokens, positions,
                                                          swapped)
        new_caches = jax.tree.map(lambda a: jnp.moveaxis(a, 0, 1), new_sw)
        return logits, new_caches

    # -- public API ----------------------------------------------------------

    def submit(self, prompt: List[int], max_new_tokens: int = 16,
               temperature: float = 0.0, eos_id: Optional[int] = None) -> int:
        req = Request(next(self._rid), list(prompt), max_new_tokens,
                      temperature, eos_id)
        self.queue.append(req)
        return req.rid

    def step(self) -> List[Request]:
        """Admit, decode one token for every active slot, retire finished.
        Returns requests completed during this step."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if not s.free]
        if not active:
            return []
        tokens = jnp.asarray(
            [s.pending.pop(0) if s.pending else (s.request.output[-1]
             if not s.free and s.request.output else 0)
             for s in self.slots], jnp.int32)
        positions = jnp.asarray([s.pos for s in self.slots], jnp.int32)
        logits, self.caches = self._decode(self.qparams, tokens,
                                           self.caches, positions)
        self._step_i += 1
        key = jax.random.fold_in(self._key, self._step_i)
        next_tokens = sample(logits, key, 0.0)
        finished = []
        for i, slot in enumerate(self.slots):
            if slot.free:
                continue
            slot.pos += 1
            if slot.pending:        # still consuming the prompt
                continue
            req = slot.request
            tok = int(next_tokens[i])
            if req.temperature > 0:
                tok = int(sample(logits[i][None],
                                 jax.random.fold_in(key, i),
                                 req.temperature)[0])
            req.output.append(tok)
            hit_eos = req.eos_id is not None and tok == req.eos_id
            if len(req.output) >= req.max_new_tokens or hit_eos or \
                    slot.pos >= self.max_context - 1:
                req.done = True
                finished.append(req)
                self.slots[i] = _Slot()     # slot returns to the pool
        return finished

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_steps):
            done += self.step()
            if not self.queue and all(s.free for s in self.slots):
                break
        return done

    # -- internals -----------------------------------------------------------

    def _admit(self):
        for i, slot in enumerate(self.slots):
            if not slot.free or not self.queue:
                continue
            req = self.queue.popleft()
            # reset this slot's cache rows, then stream the prompt through
            self.caches = jax.tree.map(
                lambda a: a.at[:, i].set(jnp.zeros_like(a[:, i])),
                self.caches)
            self.slots[i] = _Slot(request=req, pos=0,
                                  pending=list(req.prompt))

    @property
    def utilization(self) -> float:
        busy = sum(not s.free for s in self.slots)
        return busy / max(len(self.slots), 1)
