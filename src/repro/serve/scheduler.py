"""Continuous-batching request scheduler over the serve engine.

Production serving rarely sees aligned batches: requests arrive with
different prompt lengths and different generation budgets. The scheduler
maintains a fixed pool of `slots` (the jitted decode step has a static
batch dimension), admits queued requests into free slots between decode
steps, and retires sequences as they hit their token budget or EOS —
classic continuous batching (Orca/vLLM style) expressed with a *static*
batch so nothing ever recompiles.

Per-slot state lives in the shared caches at distinct batch rows; admission
"prefills" a new prompt by running single-row decode steps over the prompt
tokens (CPU-friendly and shape-stable; on TPU a dedicated row-prefill with
the full prefill kernel would amortize this — noted in DESIGN.md).

Overload & fault behavior (docs/serving.md has the full contract):

* Every submitted request reaches EXACTLY ONE typed terminal status —
  ``ok | rejected | timed_out | evicted | failed`` — recorded in
  ``ContinuousBatcher.terminal``. Admission control rejects over-long
  prompts (they would silently wrap the ring cache) and queue-full
  submissions at ``submit()``; queued requests whose deadline passes are
  expired as ``timed_out``.
* Fault tolerance: serving state is reconstructible from the request
  JOURNAL (``serve/journal.py`` — append-only, flushed per event). On
  replica loss, ``ContinuousBatcher.recover`` rebuilds a batcher that
  re-admits every request the dead replica never finished. A slot whose
  decode produces non-finite logits is quarantined (cache row reset) and
  its request re-admitted from scratch within a bounded per-request retry
  budget; transient decode errors are retried in-step first.
* Degradation (AdaBits-style): under queue pressure a
  ``serve/policy.PrecisionPolicy`` drops the serving word length; the
  batcher swaps between pre-materialized qparam trees of identical pytree
  structure, so the jitted decode NEVER recompiles across precision
  switches.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import Config
from repro.models import transformer
from repro.serve.engine import (quantize_for_serving,
                                quantize_serving_levels, sample)
from repro.serve.faults import FaultInjector, TransientDecodeError
from repro.serve.journal import RequestJournal
from repro.serve.policy import PrecisionPolicy

Array = jax.Array


class Status(str, enum.Enum):
    """Request lifecycle. PENDING/ACTIVE are transient; the rest are the
    typed TERMINAL statuses of the serving contract."""
    PENDING = "pending"        # queued, not yet in a slot
    ACTIVE = "active"          # owns a slot
    OK = "ok"                  # completed its token budget / EOS
    REJECTED = "rejected"      # refused at admission (typed ``reason``)
    TIMED_OUT = "timed_out"    # deadline passed while queued
    EVICTED = "evicted"        # replica shutdown; re-admittable elsewhere
    FAILED = "failed"          # decode faults exhausted the retry budget


TERMINAL = frozenset((Status.OK, Status.REJECTED, Status.TIMED_OUT,
                      Status.EVICTED, Status.FAILED))


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    temperature: float = 0.0
    eos_id: Optional[int] = None
    deadline: Optional[float] = None    # absolute, on the batcher's clock
    submit_time: float = 0.0
    # filled by the scheduler
    output: List[int] = dataclasses.field(default_factory=list)
    status: Status = Status.PENDING
    reason: str = ""                    # set with REJECTED/TIMED_OUT/FAILED
    retries_left: int = 0

    @property
    def done(self) -> bool:
        return self.status in TERMINAL


class DrainTimeout(RuntimeError):
    """``run_until_drained`` hit its step budget with work still in
    flight. Carries the drain report instead of silently stranding it."""

    def __init__(self, unfinished, done, steps):
        self.unfinished = tuple(unfinished)   # rids still queued/active
        self.done = done                      # requests finished so far
        self.steps = steps
        super().__init__(
            f"run_until_drained: {len(self.unfinished)} request(s) still "
            f"in flight after {steps} steps: {sorted(self.unfinished)}")


@dataclasses.dataclass
class _Slot:
    request: Optional[Request] = None
    pos: int = 0                 # absolute position of the next token
    pending: List[int] = dataclasses.field(default_factory=list)

    @property
    def free(self) -> bool:
        return self.request is None


class ContinuousBatcher:
    """Explicit kwargs override ``cfg.serve``; ``clock`` must be monotonic
    (injectable for deterministic deadline tests)."""

    def __init__(self, cfg: Config, params, adapt_state=None, *,
                 slots: Optional[int] = None,
                 max_context: Optional[int] = None, seed: int = 0,
                 max_queue: Optional[int] = None,
                 retry_budget: Optional[int] = None,
                 transient_retries: Optional[int] = None,
                 default_timeout: Optional[float] = None,
                 policy: Optional[PrecisionPolicy] = None,
                 faults: Optional[FaultInjector] = None,
                 journal_path: str = "",
                 clock: Callable[[], float] = time.monotonic):
        scfg = cfg.serve
        self.cfg = cfg
        self.m = cfg.model
        n_slots = slots if slots is not None else scfg.slots
        self.slots = [_Slot() for _ in range(n_slots)]
        self.max_context = (max_context if max_context is not None
                            else scfg.max_context)
        self.max_queue = max_queue if max_queue is not None else scfg.max_queue
        self.retry_budget = (retry_budget if retry_budget is not None
                             else scfg.retry_budget)
        self.transient_retries = (transient_retries
                                  if transient_retries is not None
                                  else scfg.transient_retries)
        self.default_timeout = (default_timeout if default_timeout is not None
                                else scfg.default_timeout)
        self.clock = clock
        self.policy = policy
        self.faults = faults
        self.journal = RequestJournal(journal_path) if journal_path else None
        adapt_state = adapt_state or {}
        # AdaBits degradation: one pre-materialized word set per level,
        # structurally identical trees (asserted at load), swapped between
        # steps. Without a policy (or without controller state) there is a
        # single tree and the swap machinery is inert.
        if policy is not None:
            self.qparam_levels = quantize_serving_levels(
                params, adapt_state, cfg.quant, policy.levels)
            self.active_wl = next(iter(self.qparam_levels))
            self.qparams = self.qparam_levels[self.active_wl]
        else:
            self.qparam_levels = {}
            self.active_wl = None
            self.qparams = quantize_for_serving(params, adapt_state,
                                                cfg.quant)
        self.queue: collections.deque = collections.deque()
        self.terminal: Dict[int, Request] = {}   # rid → request, set once
        self.wl_trace: List[int] = []            # active WL per step
        self.stats = collections.Counter()
        self._next_rid = 0
        self._key = jax.random.PRNGKey(seed)
        self._step_i = 0
        self._waits: collections.deque = collections.deque(maxlen=256)
        self.caches = transformer.init_caches(self.m, n_slots,
                                              self.max_context)
        # one decode step over the whole slot pool; per-slot positions
        self._decode = jax.jit(self._decode_fn)

    def _decode_fn(self, qparams, tokens, caches, positions):
        """tokens: (S,) int32 per slot; positions: (S,) int32 per slot.
        Uses per-slot positions by vmapping the single-row decode."""
        m = self.m

        def one(tok, pos, cache_row):
            cache1 = jax.tree.map(lambda a: a[:, None], cache_row)
            logits, new1 = transformer.decode_step(
                qparams, m, tok[None], cache1, pos,
                use_pallas=self.cfg.quant.use_pallas)
            return logits[0], jax.tree.map(lambda a: a[:, 0], new1)

        # move the batch axis (dim 1 of (NP, B, ...)) to the front for vmap
        swapped = jax.tree.map(lambda a: jnp.moveaxis(a, 1, 0), caches)
        logits, new_sw = jax.vmap(one, in_axes=(0, 0, 0))(tokens, positions,
                                                          swapped)
        new_caches = jax.tree.map(lambda a: jnp.moveaxis(a, 0, 1), new_sw)
        return logits, new_caches

    # -- public API ----------------------------------------------------------

    def submit(self, prompt: List[int], max_new_tokens: int = 16,
               temperature: float = 0.0, eos_id: Optional[int] = None, *,
               deadline: Optional[float] = None,
               timeout: Optional[float] = None,
               rid: Optional[int] = None) -> Request:
        """Admit a request (returns it, possibly already REJECTED with a
        typed ``reason``). ``timeout`` is seconds-from-now sugar for
        ``deadline``; ``cfg.serve.default_timeout`` applies when neither
        is given. ``rid`` is for journal replay only."""
        now = self.clock()
        if timeout is None and deadline is None and self.default_timeout > 0:
            timeout = self.default_timeout
        if deadline is None and timeout is not None:
            deadline = now + timeout
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid + 1)
        req = Request(rid, list(prompt), max_new_tokens, temperature, eos_id,
                      deadline=deadline, submit_time=now,
                      retries_left=self.retry_budget)
        self.stats["submitted"] += 1
        if self.journal is not None:
            self.journal.record_submit(req)
        if len(req.prompt) >= self.max_context:
            # an over-long prompt would drain ``pending`` while ``pos``
            # wraps the ring cache, corrupting the slot — refuse it here
            self._finish(req, Status.REJECTED, "prompt_too_long")
            return req
        if self.max_queue and len(self.queue) >= self.max_queue:
            self._finish(req, Status.REJECTED, "queue_full")
            return req
        self.queue.append(req)
        return req

    def step(self) -> List[Request]:
        """Expire, (maybe) swap precision, admit, decode one token for
        every active slot, retire finished. Returns every request that
        reached a terminal status during this step."""
        now = self.clock()
        finished = self._expire(now)
        if self.policy is not None:
            self._observe_policy()
        self._admit(now)
        active = [i for i, s in enumerate(self.slots) if not s.free]
        if not active:
            return finished
        tokens = jnp.asarray(
            [s.pending.pop(0) if s.pending else (s.request.output[-1]
             if not s.free and s.request.output else 0)
             for s in self.slots], jnp.int32)
        positions = jnp.asarray([s.pos for s in self.slots], jnp.int32)
        try:
            logits, self.caches = self._guarded_decode(tokens, positions)
        except TransientDecodeError as e:
            self._step_i += 1
            return finished + self._fault_all_active(str(e))
        self._step_i += 1
        key = jax.random.fold_in(self._key, self._step_i)
        next_tokens = sample(logits, key, 0.0)
        # non-finite logits = corrupted slot state (bad cache row / flipped
        # bit): quarantine before any token from it reaches an output
        finite = np.asarray(jnp.all(jnp.isfinite(logits), axis=-1))
        for i, slot in enumerate(self.slots):
            if slot.free:
                continue
            if not finite[i]:
                finished += self._quarantine(i, "non_finite_logits")
                continue
            slot.pos += 1
            if slot.pending:        # still consuming the prompt
                continue
            req = slot.request
            tok = int(next_tokens[i])
            if req.temperature > 0:
                tok = int(sample(logits[i][None],
                                 jax.random.fold_in(key, i),
                                 req.temperature)[0])
            req.output.append(tok)
            hit_eos = req.eos_id is not None and tok == req.eos_id
            if len(req.output) >= req.max_new_tokens or hit_eos or \
                    slot.pos >= self.max_context - 1:
                self._finish(req, Status.OK)
                finished.append(req)
                self.slots[i] = _Slot()     # slot returns to the pool
        return finished

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        """Step until queue and slots are empty; returns the requests that
        reached a terminal status. Raises ``DrainTimeout`` (naming the
        stranded request ids, with the partial results attached) instead
        of silently returning with work still in flight."""
        done: List[Request] = []
        for _ in range(max_steps):
            done += self.step()
            if not self.queue and all(s.free for s in self.slots):
                return done
        raise DrainTimeout(self._in_flight_rids(), done, max_steps)

    def evict_all(self, reason: str = "replica_shutdown") -> List[Request]:
        """Graceful replica shutdown: every queued/active request becomes
        ``evicted`` (terminal here; journal replay re-admits evicted
        requests on the replacement replica)."""
        out = []
        for i, slot in enumerate(self.slots):
            if not slot.free:
                self._finish(slot.request, Status.EVICTED, reason)
                out.append(slot.request)
                self.slots[i] = _Slot()
        while self.queue:
            req = self.queue.popleft()
            self._finish(req, Status.EVICTED, reason)
            out.append(req)
        return out

    @classmethod
    def recover(cls, cfg: Config, params, adapt_state=None, *,
                journal_path: str, **kwargs) -> "ContinuousBatcher":
        """Rebuild a batcher after replica loss: re-admit (preserving rids)
        every journaled request that never reached a terminal status on
        the dead replica, plus explicitly evicted ones."""
        pending = RequestJournal.unfinished(journal_path)
        cb = cls(cfg, params, adapt_state, journal_path=journal_path,
                 **kwargs)
        for ev in pending:
            cb.submit(ev["prompt"], ev["max_new_tokens"],
                      ev.get("temperature", 0.0), ev.get("eos_id"),
                      deadline=ev.get("deadline"), rid=ev["rid"])
        return cb

    @property
    def utilization(self) -> float:
        busy = sum(not s.free for s in self.slots)
        return busy / max(len(self.slots), 1)

    def p95_wait_ms(self) -> float:
        """p95 queue wait (submit → admission) over the recent window."""
        if not self._waits:
            return 0.0
        waits = sorted(self._waits)
        return waits[int(0.95 * (len(waits) - 1))] * 1e3

    # -- internals -----------------------------------------------------------

    def _in_flight_rids(self) -> List[int]:
        return ([r.rid for r in self.queue]
                + [s.request.rid for s in self.slots if not s.free])

    def _finish(self, req: Request, status: Status, reason: str = ""):
        """The single terminal transition. Asserts exactly-once."""
        if req.status in TERMINAL:
            raise AssertionError(
                f"request {req.rid} reached a second terminal status "
                f"{status.value!r} (already {req.status.value!r})")
        req.status = status
        req.reason = reason
        self.terminal[req.rid] = req
        self.stats[status.value] += 1
        if self.journal is not None:
            self.journal.record_terminal(req)

    def _expire(self, now: float) -> List[Request]:
        """Expire queued requests whose deadline passed (typed, exact)."""
        expired = [r for r in self.queue
                   if r.deadline is not None and now > r.deadline]
        if expired:
            self.queue = collections.deque(
                r for r in self.queue if r not in expired)
            for req in expired:
                self._finish(req, Status.TIMED_OUT, "deadline_expired")
        return expired

    def _observe_policy(self):
        wl = self.policy.observe(len(self.queue), self.p95_wait_ms())
        if wl in self.qparam_levels and wl != self.active_wl:
            # same treedef/shapes/dtypes (asserted at load): the jitted
            # decode sees identical avals and never recompiles
            self.qparams = self.qparam_levels[wl]
            self.active_wl = wl
            self.stats["precision_switches"] += 1
        self.wl_trace.append(self.active_wl if self.active_wl is not None
                             else self.policy.wl)

    def _admit(self, now: float):
        for i, slot in enumerate(self.slots):
            if not slot.free or not self.queue:
                continue
            req = self.queue.popleft()
            self._waits.append(now - req.submit_time)
            req.status = Status.ACTIVE
            # reset this slot's cache rows, then stream the prompt through
            self.caches = jax.tree.map(
                lambda a: a.at[:, i].set(jnp.zeros_like(a[:, i])),
                self.caches)
            self.slots[i] = _Slot(request=req, pos=0,
                                  pending=list(req.prompt))

    def _guarded_decode(self, tokens, positions):
        """Decode with fault-injection hooks and bounded in-step retry of
        transient errors. A raising decode never touched ``self.caches``
        (the exception propagates before assignment), so retry is safe."""
        attempts = self.transient_retries + 1
        for attempt in range(attempts):
            try:
                if self.faults is not None:
                    self.faults.before_decode(self._step_i, attempt)
                logits, caches = self._decode(self.qparams, tokens,
                                              self.caches, positions)
            except TransientDecodeError:
                self.stats["transient_decode_errors"] += 1
                if attempt == attempts - 1:
                    raise
                continue
            if self.faults is not None:
                logits = self.faults.corrupt_logits(self._step_i, logits)
            return logits, caches

    def _quarantine(self, i: int, reason: str) -> List[Request]:
        """Slot ``i`` produced corrupt output: zero its cache rows so the
        poisoned state cannot leak into a future occupant, free it, and
        re-admit (or fail) the victim."""
        req = self.slots[i].request
        self.caches = jax.tree.map(
            lambda a: a.at[:, i].set(jnp.zeros_like(a[:, i])), self.caches)
        self.slots[i] = _Slot()
        self.stats["quarantines"] += 1
        return self._readmit_or_fail(req, reason)

    def _fault_all_active(self, reason: str) -> List[Request]:
        """In-step retries exhausted with no logits at all: every active
        request is a victim. Caches were never touched by the raising
        decode, but the slots restart their requests from scratch."""
        out = []
        for i, slot in enumerate(self.slots):
            if slot.free:
                continue
            req = slot.request
            self.slots[i] = _Slot()
            out += self._readmit_or_fail(req, reason)
        return out

    def _readmit_or_fail(self, req: Request, reason: str) -> List[Request]:
        """Bounded per-request retry: re-admit from scratch (front of the
        queue — the victim already waited) while budget remains, else the
        typed ``failed`` terminal."""
        if req.retries_left > 0:
            req.retries_left -= 1
            req.output = []
            req.status = Status.PENDING
            self.queue.appendleft(req)
            self.stats["retries"] += 1
            return []
        self._finish(req, Status.FAILED, reason)
        return [req]
