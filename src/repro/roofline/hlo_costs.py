"""Trip-count-aware HLO cost walker.

``compiled.cost_analysis()`` counts every while-loop body ONCE (verified in
this container: a scan of 8 matmuls reports the flops of 1) — useless for a
scanned-layers training step whose inner loop runs accum×num_layers times.
The same defect hits any naive collective-bytes grep.

This walker parses the post-partitioning HLO text into a call graph
(computations, while/fusion/call/conditional edges), extracts loop trip
counts from scan-shaped conditions (`compare(iter, constant(N)), LT`), and
accumulates per-chip:

    flops             — dot/convolution, 2·prod(result)·prod(contracted)
    bytes             — Σ result bytes of top-level ops (HBM-traffic proxy:
                        fusion internals stay in registers/VMEM)
    collectives[kind] — Σ result bytes of all-reduce/all-gather/
                        reduce-scatter/all-to-all/collective-permute

Each multiplied by the product of enclosing trip counts. Dynamic-bound
loops (none in this codebase's jit graphs) fall back to ×1 and are flagged.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*([a-z0-9]+)\[([0-9,]*)\][^\s]*\s+"
    r"([a-z0-9\-]+)\(")
_TUPLE_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*\(.*\)\s+([a-z0-9\-]+)\(")
_COMP_HDR_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_COND_BODY_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"(?:true_computation=%?([\w\.\-]+).*?"
                          r"false_computation=%?([\w\.\-]+)|"
                          r"branch_computations=\{([^}]*)\})")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_WINDOW_SIZE_RE = re.compile(r"size=([0-9x]+)")


@dataclass
class Op:
    name: str
    kind: str
    dtype: str
    dims: Tuple[int, ...]
    line: str


@dataclass
class Computation:
    name: str
    is_entry: bool = False
    ops: List[Op] = field(default_factory=list)
    shapes: Dict[str, Tuple[str, Tuple[int, ...]]] = field(default_factory=dict)


def _parse_dims(s: str) -> Tuple[int, ...]:
    return tuple(int(d) for d in s.split(",") if d) if s else ()


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if hdr and "{" in line and "=" not in line.split("(")[0]:
            cur = Computation(hdr.group(2), is_entry=bool(hdr.group(1)))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _DEF_RE.match(line)
        if m:
            name, dtype, dims_s, kind = m.groups()
            dims = _parse_dims(dims_s)
            cur.shapes[name] = (dtype, dims)
            cur.ops.append(Op(name, kind, dtype, dims, line.strip()))
            continue
        mt = _TUPLE_DEF_RE.match(line)
        if mt:
            name, kind = mt.groups()
            # tuple-shaped op (while/fusion returning tuples): record shapes
            # of tuple elements for byte counting of collectives if needed
            cur.shapes[name] = ("tuple", ())
            cur.ops.append(Op(name, kind, "tuple", (), line.strip()))
        # parameters: "%p = f32[...] parameter(0)" matched by _DEF_RE above
    return comps


def _prod(dims) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _dot_flops(op: Op, comp: Computation) -> float:
    mc = _CONTRACT_RE.search(op.line)
    inside = op.line[op.line.index("(") + 1:]
    operands = _OPERAND_RE.findall(inside.split(")")[0])
    lhs = comp.shapes.get(operands[0]) if operands else None
    contracted = 1
    if mc and lhs:
        for d in _parse_dims(mc.group(1)):
            if d < len(lhs[1]):
                contracted *= lhs[1][d]
    return 2.0 * _prod(op.dims) * contracted


def _conv_flops(op: Op, comp: Computation) -> float:
    inside = op.line[op.line.index("(") + 1:]
    operands = _OPERAND_RE.findall(inside.split(")")[0])
    if len(operands) < 2:
        return 0.0
    rhs = comp.shapes.get(operands[1])
    if rhs is None:
        return 0.0
    # kernel: spatial dims × input features (HWIO-ish); output features is
    # in the result shape, so multiply result elements by prod(kernel)/O
    kdims = _prod(rhs[1])
    ofeat = rhs[1][-1] if rhs[1] else 1
    per_out = kdims / max(ofeat, 1)
    return 2.0 * _prod(op.dims) * per_out


def _trip_count(cond: Computation) -> Tuple[float, bool]:
    consts = [int(c) for op in cond.ops for c in _CONST_RE.findall(op.line)]
    big = [c for c in consts if c > 0]
    if big:
        return float(max(big)), True
    return 1.0, False


def _op_bytes(op: Op) -> float:
    return float(_prod(op.dims)) * _DTYPE_BYTES.get(op.dtype, 4)


def _collective_payload_bytes(op: Op, comp: Computation,
                              comps: Dict[str, Computation]) -> float:
    """Wire bytes of a collective, seeing through the CPU backend's
    promotion pass: XLA-CPU cannot reduce/gather bf16/int8, so it emits
    convert-up → collective(f32) → convert-down. On the TPU target the
    payload stays narrow. If the collective's operand is produced by a
    convert (or a fusion whose same-shaped parameter is narrower), count
    the narrow dtype; genuinely-f32 payloads are unaffected (their
    producers' same-shape inputs are f32 too)."""
    result = _op_bytes(op)
    inside = op.line[op.line.index("(") + 1:]
    operands = _OPERAND_RE.findall(inside.split(")")[0])
    if not operands:
        return result
    src = next((o for o in comp.ops if o.name == operands[0]), None)
    if src is None:
        return result
    width = _DTYPE_BYTES.get(op.dtype, 4)
    narrow = width
    if src.kind == "convert":
        ins = _OPERAND_RE.findall(src.line[src.line.index("(") + 1:])
        if ins and ins[0] in comp.shapes:
            narrow = _DTYPE_BYTES.get(comp.shapes[ins[0]][0], width)
    elif src.kind == "fusion":
        m = _CALLS_RE.search(src.line)
        body = comps.get(m.group(1)) if m else None
        if body is not None:
            n_elem = _prod(src.dims)
            # (a) a same-sized parameter that is already narrow
            for o in body.ops:
                if o.kind == "parameter" and o.dims != () and \
                        _prod(o.dims) == n_elem:
                    narrow = min(narrow, _DTYPE_BYTES.get(o.dtype, width))
            # (b) a narrow→wide convert round-trip feeding the result (the
            # promotion pass materializes convert(bf16→f32) right before
            # the wire) — the convert INPUT dtype is the true payload
            for o in body.ops:
                if o.kind != "convert" or _prod(o.dims) != n_elem:
                    continue
                ins = _OPERAND_RE.findall(o.line[o.line.index("(") + 1:])
                if ins and ins[0] in body.shapes:
                    w_in = _DTYPE_BYTES.get(body.shapes[ins[0]][0], width)
                    if w_in < _DTYPE_BYTES.get(o.dtype, width):
                        narrow = min(narrow, w_in)
    if narrow < width:
        return result * narrow / width
    return result


class Walker:
    def __init__(self, comps: Dict[str, Computation]):
        self.comps = comps
        self.memo: Dict[str, Dict] = {}
        self.dynamic_loops = 0
        # computations called as fusion bodies: their op "bytes" are
        # register/VMEM-internal, skip byte counting there
        self.fusion_bodies = set()
        for c in comps.values():
            for op in c.ops:
                if op.kind == "fusion":
                    m = _CALLS_RE.search(op.line)
                    if m:
                        self.fusion_bodies.add(m.group(1))

    def costs(self, name: str) -> Dict:
        if name in self.memo:
            return self.memo[name]
        comp = self.comps.get(name)
        if comp is None:
            return {"flops": 0.0, "bytes": 0.0, "coll": {}}
        total = {"flops": 0.0, "bytes": 0.0, "coll": {}}
        in_fusion = name in self.fusion_bodies
        for op in comp.ops:
            if op.kind == "dot":
                total["flops"] += _dot_flops(op, comp)
            elif op.kind == "convolution":
                total["flops"] += _conv_flops(op, comp)
            if not in_fusion and op.kind not in ("parameter", "constant",
                                                 "get-tuple-element", "tuple"):
                total["bytes"] += _op_bytes(op)
            if op.kind in COLLECTIVES or any(
                    op.kind == k + "-start" for k in COLLECTIVES):
                kind = op.kind.replace("-start", "")
                total["coll"][kind] = total["coll"].get(kind, 0.0) \
                    + _collective_payload_bytes(op, comp, self.comps)
            if op.kind == "while":
                m = _COND_BODY_RE.search(op.line)
                if m:
                    cond_name, body_name = m.groups()
                    trips, static = _trip_count(self.comps.get(
                        cond_name, Computation(cond_name)))
                    if not static:
                        self.dynamic_loops += 1
                    self._add(total, self.costs(body_name), trips)
                    self._add(total, self.costs(cond_name), trips)
            elif op.kind in ("fusion", "call", "custom-call", "map",
                             "reduce", "reduce-window", "sort", "scatter"):
                m = _CALLS_RE.search(op.line)
                if m:
                    self._add(total, self.costs(m.group(1)), 1.0)
            elif op.kind == "conditional":
                m = _BRANCHES_RE.search(op.line)
                if m:
                    branches = [b for b in (m.group(1), m.group(2)) if b]
                    if m.group(3):
                        branches = _OPERAND_RE.findall(m.group(3)) or \
                            [s.strip().lstrip("%") for s in
                             m.group(3).split(",")]
                    if branches:
                        subs = [self.costs(b) for b in branches]
                        worst = max(subs, key=lambda s: s["flops"] + s["bytes"])
                        self._add(total, worst, 1.0)
        self.memo[name] = total
        return total

    @staticmethod
    def _add(total: Dict, sub: Dict, mult: float):
        total["flops"] += sub["flops"] * mult
        total["bytes"] += sub["bytes"] * mult
        for k, v in sub["coll"].items():
            total["coll"][k] = total["coll"].get(k, 0.0) + v * mult


def xla_cost_analysis(compiled) -> Optional[Dict]:
    """``compiled.cost_analysis()`` normalized across JAX versions: older
    jaxlibs return a one-element list of per-device dicts, newer ones the
    dict itself. Returns None when XLA provides nothing."""
    cost = compiled.cost_analysis()
    if not cost:
        return None
    return cost if isinstance(cost, dict) else cost[0]


def module_costs(hlo_text: str) -> Dict:
    """Per-chip {flops, bytes, collectives{kind: bytes}, dynamic_loops}."""
    comps = parse_module(hlo_text)
    entry = next((c.name for c in comps.values() if c.is_entry), None)
    if entry is None:  # fall back: biggest computation
        entry = max(comps, key=lambda n: len(comps[n].ops)) if comps else None
    w = Walker(comps)
    out = w.costs(entry) if entry else {"flops": 0.0, "bytes": 0.0, "coll": {}}
    coll = dict(out["coll"])
    coll["total"] = sum(coll.values())
    return {"flops": out["flops"], "bytes": out["bytes"],
            "collectives": coll, "dynamic_loops": w.dynamic_loops}
