"""Three-term roofline analysis from compiled dry-run artifacts.

    compute   = HLO_FLOPs / (chips × peak_FLOP/s)
    memory    = HLO_bytes / (chips × HBM_bw)
    collective= collective_bytes / (chips × link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are NOT
in cost_analysis, so we parse the (partitioned) HLO text and sum operand
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops.

Hardware constants (TPU v5e, per task spec):
    197 TFLOP/s bf16 per chip · 819 GB/s HBM · ~50 GB/s/link ICI.

MODEL_FLOPS (6·N·D dense, 6·N_active·D MoE) anchors a usefulness ratio —
how much of the compiled compute is the model itself vs remat/overhead.
"""
from __future__ import annotations

import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
PEAK_FLOPS_INT8 = 394e12     # int8 MXU path (2× bf16) — native_int8 mode
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

# `%x = f32[8,128]{1,0} all-reduce(...)` — possibly tuple-shaped
_COLL_RE = re.compile(
    r"=\s*(\(?[a-z0-9\[\],{}\s/#_\.]*?\)?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.IGNORECASE)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes per collective kind over the HLO module.

    The partitioned module is per-device, so these are bytes *per chip* per
    step — exactly the numerator the collective roofline term wants.
    """
    out: Dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_text, kind = m.group(1), m.group(2).lower()
        out[kind] = out.get(kind, 0.0) + _shape_bytes(shape_text)
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def roofline_terms(record: Dict, *, chips: Optional[int] = None,
                   peak_flops: float = PEAK_FLOPS) -> Dict[str, float]:
    """Derive the three terms (seconds) from a dry-run record.

    cost_analysis on the partitioned program reports per-device numbers, so
    each term divides by per-chip capability only.
    """
    cost = record.get("cost", {})
    coll = record.get("collectives", {})
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    coll_bytes = float(coll.get("total", 0.0))
    terms = {
        "compute_s": flops / peak_flops,
        "memory_s": bytes_acc / HBM_BW,
        "collective_s": coll_bytes / ICI_BW,
    }
    terms["bottleneck"] = max(terms, key=lambda k: terms[k])
    terms["step_s_lower_bound"] = max(
        terms["compute_s"], terms["memory_s"], terms["collective_s"])
    return terms


def model_flops(cfg, *, per_chip: bool = True, chips: int = 256) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) per optimizer step, forward+backward.

    N excludes embedding lookups (standard convention); MoE counts only the
    activated experts (top-k of E)."""
    m, t = cfg.model, cfg.train
    from repro.models import transformer
    import jax
    shapes = jax.eval_shape(
        lambda: transformer.init_params(jax.random.PRNGKey(0), m))
    total = 0
    active = 0
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    for path, leaf in flat:
        p = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path)
        n = 1
        for d in leaf.shape:
            n *= d
        if "embed" in p or "head" in p:
            continue
        total += n
        if "we_" in p and m.num_experts:
            active += n * m.experts_per_token / m.num_experts
        else:
            active += n
    tokens = t.global_batch * max(t.seq_len, 1)
    f = 6.0 * active * tokens
    return f / chips if per_chip else f


def usefulness(record: Dict, cfg, chips: int = 256) -> float:
    """MODEL_FLOPS / HLO_FLOPs — catches remat/redundancy waste."""
    hlo = float(record.get("cost", {}).get("flops", 0.0))
    if hlo <= 0:
        return 0.0
    return model_flops(cfg, per_chip=True, chips=chips) / hlo


def format_row(record: Dict, terms: Dict[str, float]) -> str:
    c = record.get("collectives", {})
    return (f"| {record['arch']} | {record['shape']} | "
            f"{terms['compute_s'] * 1e3:.2f} | {terms['memory_s'] * 1e3:.2f} | "
            f"{terms['collective_s'] * 1e3:.2f} | {terms['bottleneck'].replace('_s', '')} | "
            f"{c.get('total', 0) / 1e9:.2f} GB |")
