"""Config system for the repro framework.

Plain dataclasses (no external deps), a registry populated by
``repro.configs``, and dotted-path CLI overrides:

    cfg = load_config("granite-8b", shape="train_4k",
                      overrides=["quant.mode=native_int8", "train.steps=100"])
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple

# ---------------------------------------------------------------------------
# Model


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | vlm | audio | cnn
    num_layers: int = 2
    d_model: int = 128
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0          # 0 -> d_model // num_heads
    d_ff: int = 512
    vocab_size: int = 512
    # attention flavour
    attn_pattern: Tuple[str, ...] = ("global",)   # cycled over layers: global|local
    window_size: int = 4096                       # for "local"/SWA layers
    attn_logit_softcap: float = 0.0               # gemma2: 50.0
    final_logit_softcap: float = 0.0              # gemma2: 30.0
    rope_theta: float = 10000.0
    use_qk_norm: bool = False
    # layer kind pattern (cycled): attn | mamba | cross  — transformer block kind
    layer_pattern: Tuple[str, ...] = ("attn",)
    shared_attn_weights: bool = False             # zamba2: attn blocks share weights
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                             # 0 -> d_ff
    dense_residual_d_ff: int = 0                  # arctic: parallel dense FFN
    capacity_factor: float = 1.25
    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    # VLM
    cross_attn_every: int = 0                     # >0: cross-attn block every k layers
    num_image_tokens: int = 1024                  # stub frontend output length
    # audio / encoder
    is_encoder: bool = False
    num_input_frames: int = 1024                  # stub frontend output length
    # misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    scale_embed: bool = False                     # gemma2: embed * sqrt(d)
    act_fn: str = "silu"                          # silu | gelu
    use_post_norm: bool = False                   # gemma2 post-attn/ffn norms

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def is_attention_free(self) -> bool:
        return all(k == "mamba" for k in self.layer_pattern)

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic archs: every layer is mamba or windowed attention."""
        if self.is_encoder:
            return False
        if any(k == "cross" for k in self.layer_pattern):
            return False
        kinds = set(self.layer_pattern)
        if kinds == {"mamba"}:
            return True
        if "attn" in kinds and all(p == "local" for p in self.attn_pattern):
            return True
        # hybrid: mamba + (any) attention is fine — attention layers are few
        return "mamba" in kinds


# ---------------------------------------------------------------------------
# Quantization (AdaPT)


@dataclass(frozen=True)
class QuantConfig:
    # off: no quantize, no controller state, qparams = params. simulate:
    # grid values in a float container (paper-faithful). native_int8:
    # int8 words + 2^-FL scale; with container_dtype="int8_packed" the
    # words travel the mesh as 1-byte payloads AND feed the dense Pallas
    # kernels directly (see use_pallas below).
    mode: str = "simulate"        # off | simulate | native_int8
    init_wl: int = 8
    init_fl: int = 4
    buff: int = 4                 # buffer bits (paper §3.3)
    max_wl: int = 32
    r_lwr: int = 50
    r_upr: int = 150
    lb_lwr: int = 25
    lb_upr: int = 100
    gamma: float = 0.33           # lookback momentum
    eps_kl: float = 1e-2          # "KL == 0" tolerance (bits)
    strategy: str = "mean"        # initial push-up strategy: min | mean | max
    # quantize_activations: per-slot dynamic-range activation quantize at
    # the layer's WL (STE gradient). Purely elementwise — it never changes
    # kernel dispatch (the flash/dense kernels see the quantized values).
    quantize_activations: bool = True
    # stochastic_rounding=False forces RTN everywhere, which ALSO disables
    # the in-kernel-PRNG quantize (controller._use_fused_prng: the fused
    # kernel is an SR kernel) — RTN leaves take the deterministic XLA path.
    # Dense-prologue leaves stay on the kernel path either way (mode 0 is
    # in-kernel round-half-even, bit-identical to the XLA jnp.round path).
    stochastic_rounding: bool = True
    edf_sample: int = 65536       # PushDown EDF subsample size per tensor
    loss_hist_len: int = 128      # ring buffer for strategy adaptation
    # container dtype of the quantized forward copy. float32 = bit-exact
    # ⟨WL,FL⟩ grid for all WL≤24 (paper-faithful / QPyTorch-equivalent);
    # bfloat16 halves every weight gather/all-reduce byte but is only exact
    # for WL≤8 (8-bit mantissa) — beyond-paper §Perf lever, deviation
    # documented in EXPERIMENTS.md. int8 = int8 words dequantized at the
    # producer; int8_packed = lazy ⟨q8, sc, wref⟩ dicts dequantized at the
    # USE site (weights cross the mesh as 1 byte/param) — and the ONLY
    # container that feeds the dense Pallas kernel path (use_pallas below):
    # float-container grids always reach the model as plain XLA tensors.
    container_dtype: str = "float32"
    # sub-tensor exclusions (substring match on param path): these leaves
    # are never quantized and always reach the model as plain arrays —
    # independent of every dispatch flag below.
    exclude: Tuple[str, ...] = ("router", "norm", "a_log", "dt_bias", "scale")
    # --- Pallas dispatch flags -------------------------------------------
    # use_pallas routes the WHOLE train step through the fused TPU kernels
    # (interpret mode on CPU, so CI exercises the same code):
    #   * quantize_params / quantize_params_packed → sr_quantize_fused[:_int8]
    #   * precision_switch's PushDown ladder        → edf_ladder_hists
    #   * the model forward's attention              → flash_attention
    #   * the model's DENSE LAYERS (container_dtype="int8_packed"):
    #     models/common.dense feeds packed/prologue leaves straight to the
    #     fxp kernels — forward streams int8 weight tiles into the MXU
    #     (dequant in-register), dx streams the SAME tiles through a
    #     transposed index map, dw = xᵀ@dy lands straight-through on the
    #     master (kernels/ops.fxp_dense / fxp_qdense) — no dequantized
    #     weight copy exists in HBM; tests/test_dense_path.py asserts the
    #     jaxpr has fwd+dx+dw per dense layer and ZERO dequantized-weight
    #     XLA matmuls.
    #     — all of it UNDER value_and_grad: every forward op carries a
    #     custom VJP whose backward passes are Pallas kernels, pinned by
    #     tests/test_vjp_differential.py + tests/test_dense_path.py.
    # Any layer shape is eligible — primes included: the gridded kernels
    # tail-mask partial boundary blocks in-register (no divisibility
    # restriction, no whole-dim VMEM fallback; tests/test_tailmask.py).
    # Remaining exclusions: attention slots whose window arrives as a traced
    # scalar (masked XLA path), the CNN family's conv forward, non-2-D
    # quantized leaves that no dense layer consumes (embed tables, depthwise
    # conv kernels, MoE expert einsum operands — dequantized at their use
    # site as before; fixed_point.DENSE_PARAM_NAMES), and unevenly-sharded /
    # RTN-mode quantize leaves (controller._use_fused_prng).
    use_pallas: bool = False
    # fused_prng draws the stochastic-rounding noise INSIDE the quantize
    # kernel (hardware PRNG on TPU, counter-hash under interpret), so the
    # param-sized U[0,1) tensor never exists in HBM: 2 HBM transfers per
    # tensor instead of ~4. Only consulted when use_pallas is set. All
    # three leaf regimes are served (controller._use_fused_prng): scalar
    # ⟨WL,FL⟩, per-layer-stacked (L,)-vector precision (one stacked-kernel
    # launch per "blocks" leaf), and evenly-sharded leaves (shard_map-
    # wrapped kernel with per-shard folded seeds, zero collectives).
    # Noise streams are deterministic per step key but differ from the
    # jax.random stream the XLA path uses — same distribution, not same bits.
    fused_prng: bool = True
    # dense_prologue (OPT-IN) fuses the QUANTIZE into the dense matmul
    # PROLOGUE
    # (kernels/fxp_matmul.fxp_qmatmul): dense-consumed leaves skip word
    # materialization entirely — the "quantized copy" is the master plus
    # ⟨seed, FL, mode⟩, and int8 tiles are drawn in VMEM en route to the
    # MXU, killing the q8 HBM write+read-back round trip (ROADMAP's fused
    # quantize-into-matmul item). Only consulted when use_pallas is set
    # and container_dtype="int8_packed"; non-dense quantized leaves keep
    # the materialized container either way. SR always uses the PORTABLE
    # index-hash stream — a pure function of ⟨seed, element index⟩, so
    # the fwd and dx recompute agree on every word even though they tile
    # the weight differently. On CPU/interpret that makes prologue words
    # bit-identical to sr_quantize_fused_int8 on 2-D leaves; on compiled
    # TPU the MATERIALIZED kernel draws from the hardware PRNG instead,
    # so the two dispatches are same-distribution, not same-bits (same
    # caveat as fused_prng above). RTN (serving / SR off) is
    # round-half-even, bit-identical to the XLA packed path everywhere. Explicitly-
    # sharded dense leaves are EXCLUDED (they keep the materialized packed
    # container): pallas_call has no SPMD partitioning rule, and a
    # prologue dict on a mesh would gather the f32 master into every
    # launch (controller._use_dense_prologue; ROADMAP open item). Off by
    # default: the prologue re-reads the f32 MASTER once per M-block where
    # the materialized path re-reads 1-byte words, so plain HBM-bytes
    # arithmetic favors materialized words whenever the M grid has more
    # than ~2 blocks (large-batch training); enable it for
    # quantize-round-trip-bound regimes (the bench train_step rows
    # measure both). Serving always materializes regardless
    # (serve/engine.quantize_for_serving).
    dense_prologue: bool = False


# ---------------------------------------------------------------------------
# Serving (admission control / overload behavior / degradation)


@dataclass(frozen=True)
class ServeConfig:
    """Overload/robustness knobs for the continuous batcher (serve/).

    Admission control: the queue is bounded (``max_queue``; 0 = unbounded)
    and prompts that cannot fit ``max_context`` are rejected at submit()
    with a typed reason instead of silently wrapping the ring cache.
    ``default_timeout`` (seconds, 0 = off) attaches a deadline to requests
    submitted without one; queued requests whose deadline passes are
    expired with status ``timed_out``.

    Fault handling: a request whose slot produces non-finite logits (or
    whose decode step raises transiently) is re-admitted from scratch up to
    ``retry_budget`` times before being marked ``failed``; a raising decode
    is retried in-step ``transient_retries`` times first.

    Degradation (AdaBits-style, 1912.09666): under queue pressure the
    batcher swaps the active qparams tree to a lower word length from
    ``degrade_levels`` (descending; pre-materialized at load — same pytree
    structure, so the jitted decode never recompiles) and recovers when the
    queue drains, with ``degrade_patience`` consecutive observations of
    pressure/drain required per step (hysteresis). Pressure = queue depth
    ≥ ``degrade_high_watermark`` or (if ``degrade_p95_ms`` > 0) p95 queue
    wait above it; drain = depth ≤ ``degrade_low_watermark``."""
    slots: int = 4
    max_context: int = 256
    max_queue: int = 64
    default_timeout: float = 0.0
    retry_budget: int = 2
    transient_retries: int = 2
    journal_dir: str = ""             # append-only request journal ("" = off)
    degrade_levels: Tuple[int, ...] = (8, 6, 4)
    degrade_high_watermark: int = 8
    degrade_low_watermark: int = 1
    degrade_p95_ms: float = 0.0
    degrade_patience: int = 2


# ---------------------------------------------------------------------------
# Optimizer / training


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "asgd"            # asgd | sgd | adam
    lr: float = 0.05
    momentum: float = 0.0         # paper's ASGD is momentum-free
    beta1: float = 0.9
    beta2: float = 0.999
    adam_eps: float = 1e-8
    l1: float = 1e-6              # sparsifying L1 (paper)
    l2: float = 1e-5              # elastic-net L2 (paper)
    penalty_coef: float = 1e-4    # P = WL/32 * sp (paper §3.4)
    grad_normalize: bool = True   # per-tensor L2 grad normalization (paper)
    grad_clip: float = 0.0
    # ROP scheduler (paper §4.1)
    rop_factor: float = 0.5
    rop_patience: int = 10
    rop_threshold: float = 1e-3


@dataclass(frozen=True)
class TrainConfig:
    seq_len: int = 4096
    global_batch: int = 256
    microbatch_per_device: int = 1
    accum_steps: int = 1
    accum_dtype: str = "float32"  # bfloat16 halves the grad accumulator
                                  # (arctic-480b HBM headroom; DESIGN.md §3)
    steps: int = 100
    log_every: int = 10
    adapt_interval: int = 0       # 0 -> lb_lwr; cadence of precision_switch
    remat: str = "none"           # none | full | selective
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    zero_shard: bool = False      # shard master/opt state over data axis too
    fsdp: str = "auto"            # auto (by tensor size) | on | off — fold
                                  # the data axis into weight shardings
    tp_reduce_dtype: str = "float32"  # bfloat16 halves TP partial-sum
                                      # all-reduce bytes (§Perf lever)
    qsgd_pod_compression: bool = False  # int8 all-reduce across "pod" axis
    qsgd_bits: int = 8
    seed: int = 0
    checkpoint_dir: str = ""
    checkpoint_every: int = 0
    keep_checkpoints: int = 3
    async_checkpoint: bool = True
    straggler_factor: float = 3.0


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (1, 1)
    axes: Tuple[str, ...] = ("data", "model")
    # attention q-seq sharding over `model` when heads don't divide the TP
    # degree (smollm 15H, llama3.2 24H, gemma2 8H, arctic 56H on 16-way):
    # off = replicate attention (naive-TP baseline), auto = shard q-seq iff
    # heads indivisible, on = always. §Perf hillclimb lever.
    seq_shard_attn: str = "off"   # off | auto | on
    # wk/wv sharding when kv heads don't divide the TP degree: "shard" =
    # col-shard the flat projection (baseline; forces K/V activation
    # all-gathers every layer), "replicate" = keep the small wk/wv
    # replicated (no gathers, redundant kv-proj compute). §Perf lever.
    kv_proj: str = "shard"        # shard | replicate
    # decode KV-cache layout when kv heads don't divide the TP degree:
    # "heads" replicates the cache over model (baseline; attention gathers
    # the full cache every layer), "seq" shards the cache SEQUENCE over
    # model (split-KV decode: only per-head softmax stats cross chips).
    decode_kv_shard: str = "heads"  # heads | seq

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclass(frozen=True)
class Config:
    arch: str = "tiny"
    shape: str = "train_4k"
    model: ModelConfig = field(default_factory=ModelConfig)
    quant: QuantConfig = field(default_factory=QuantConfig)
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
    mesh: MeshConfig = field(default_factory=MeshConfig)
    serve: ServeConfig = field(default_factory=ServeConfig)


# ---------------------------------------------------------------------------
# Assigned input-shape sets (LM family; spec'd per task)

SHAPES = {
    "train_4k":    dict(kind="train",   seq_len=4096,   global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768,  global_batch=32),
    "decode_32k":  dict(kind="decode",  seq_len=32768,  global_batch=128),
    "long_500k":   dict(kind="decode",  seq_len=524288, global_batch=1),
}


def shape_kind(shape: str) -> str:
    return SHAPES[shape]["kind"]


# ---------------------------------------------------------------------------
# Overrides & registry


def _coerce(current: Any, raw: str) -> Any:
    if isinstance(current, bool):
        return raw.lower() in ("1", "true", "yes", "on")
    if isinstance(current, int):
        return int(raw)
    if isinstance(current, float):
        return float(raw)
    if isinstance(current, tuple):
        items = [s for s in raw.split(",") if s]
        if current and isinstance(current[0], int):
            return tuple(int(s) for s in items)
        return tuple(items)
    return raw


def apply_overrides(cfg: Config, overrides) -> Config:
    """Apply ["a.b.c=v", ...] dotted overrides to a frozen Config."""
    for ov in overrides or ():
        path, _, raw = ov.partition("=")
        keys = path.strip().split(".")
        objs = [cfg]
        for k in keys[:-1]:
            objs.append(getattr(objs[-1], k))
        value: Any = _coerce(getattr(objs[-1], keys[-1]), raw.strip())
        for parent, k in zip(reversed(objs), reversed(keys)):
            value = dataclasses.replace(parent, **{k: value})
        cfg = value
    return cfg


def with_shape(cfg: Config, shape: str) -> Config:
    s = SHAPES[shape]
    return dataclasses.replace(
        cfg, shape=shape,
        train=dataclasses.replace(cfg.train, seq_len=s["seq_len"],
                                  global_batch=s["global_batch"]))


def load_config(arch: str, shape: Optional[str] = None, overrides=None) -> Config:
    from repro.configs import get_config
    cfg = get_config(arch)
    if shape:
        cfg = with_shape(cfg, shape)
    return apply_overrides(cfg, overrides)


def config_summary(cfg: Config) -> str:
    m = cfg.model
    return (f"{cfg.arch}[{m.family}] L={m.num_layers} d={m.d_model} "
            f"H={m.num_heads}/{m.num_kv_heads} ff={m.d_ff} V={m.vocab_size} "
            f"shape={cfg.shape} quant={cfg.quant.mode}")
