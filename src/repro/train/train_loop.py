"""AdaPT-SGD training loop (paper alg. 1), unified over all model families.

Hot path = ``train_step`` (jit):
    1. L̂ = Quantize(L, Q)           — master→quantized copy at current ⟨WL,FL⟩
    2. Ĝ, L = ForwardPass(L̂, batch) — loss incl. elastic-net + P penalty,
                                       grads taken AT the quantized weights
                                       (straight-through to the master copy)
    3. controller.accumulate         — windowed gradient-diversity stats
    4. SGDBackwardsPass(L, Ĝ)        — grad-normalize → ROP → optimizer on L

Cold path = ``precision_switch`` (jit, every `adapt_interval` steps):
    PushDown + PushUp + strategy/lookback/resolution adaptation (alg. 2).

The step never branches on ⟨WL,FL⟩ values — they are traced int32 arrays —
so precision switches never recompile (DESIGN.md §5.2).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import Config
from repro.core import controller, sparsity
from repro.core import fixed_point as fxp
from repro.data import synthetic
from repro.models import cnn, transformer
from repro.quant import qsgd
from repro.train import optimizer as opt_lib

Array = jax.Array
PyTree = Any


# ---------------------------------------------------------------------------
# State


def init_state(cfg: Config, key: Optional[Array] = None) -> Dict[str, Any]:
    key = key if key is not None else jax.random.PRNGKey(cfg.train.seed)
    m = cfg.model
    if m.family == "cnn":
        init_fn, _ = cnn.MODELS[m.name.replace("-smoke", "")]
        width = 0.25 if m.name.endswith("smoke") else 1.0
        params, stats = init_fn(key, num_classes=m.vocab_size, width=width)
    else:
        params = transformer.init_params(key, m)
        stats = {}
    adapt = (controller.init_adapt_state(params, cfg.quant)
             if cfg.quant.mode != "off" else {"tensors": {}})
    return {
        "params": params,
        "stats": stats,
        "opt": opt_lib.init_opt_state(params, cfg.optimizer),
        "adapt": adapt,
        "step": jnp.int32(0),
        "rng": key,
    }


# ---------------------------------------------------------------------------
# Family-specific loss


def _task_loss(cfg: Config, qparams, stats, batch, act_wl=None,
               train: bool = True):
    """Returns (task_loss, aux dict). aux may carry new stats / accuracy."""
    m = cfg.model
    if m.family == "cnn":
        _, fwd = cnn.MODELS[m.name.replace("-smoke", "")]
        logits, new_stats = fwd(qparams, stats, batch["images"], train)
        loss = cnn.ce_loss(logits, batch["labels"])
        return loss, {"stats": new_stats,
                      "acc": cnn.accuracy(logits, batch["labels"])}
    kwargs = {}
    if m.is_encoder:
        kwargs["embeds"] = batch["embeds"]
        targets, shift = batch["labels"], False
    else:
        kwargs["tokens"] = batch["tokens"]
        targets, shift = batch["tokens"], True
    if m.cross_attn_every:
        kwargs["memory"] = batch["memory"]
    # This forward sits under value_and_grad; every forward kernel carries
    # a custom VJP whose backward passes are themselves Pallas kernels, so
    # quant.use_pallas covers the differentiated train step end to end:
    # flash attention (_flash_dq/_dkv_kernel) AND the dense layers — with
    # container_dtype="int8_packed" the packed/prologue leaves survive to
    # models/common.dense, which streams int8 weight tiles into the fxp
    # matmul kernels (dx via the same tiles transposed, straight-through
    # dw = xᵀ@dy onto the master; tests/test_dense_path.py pins fwd+dx+dw
    # per dense layer and zero dequantized-weight XLA matmuls). Remaining
    # exclusions: dynamic-window attention slots (traced window → masked
    # XLA path in attend_full), the CNN family's conv forward, and
    # non-dense quantized leaves (embed/conv/MoE-expert weights —
    # dequantized at their use site; fixed_point.DENSE_PARAM_NAMES).
    logits = transformer.forward(qparams, m, act_wl=act_wl,
                                 use_pallas=cfg.quant.use_pallas,
                                 remat=cfg.train.remat, **kwargs)
    return transformer.lm_loss(logits, targets, shift=shift), {"stats": stats}


# ---------------------------------------------------------------------------
# Train step


def make_train_step(cfg: Config, qparam_shardings=None) -> Callable:
    """``qparam_shardings``: optional NamedSharding tree for the quantized
    copy. Without it GSPMD may resolve the (sharded master × replicated SR
    noise) elementwise quantize to a REPLICATED output — i.e. all-gather the
    f32 master instead of the small quantized container (measured on
    granite-8b: the 96 GiB/step gather didn't shrink under a bf16 container
    until this constraint pinned it; EXPERIMENTS.md §Perf). Under
    ``quant.use_pallas`` + ``quant.fused_prng``, eligible leaves —
    unsharded, per-layer-stacked, AND evenly-sharded (the kernel wraps
    itself in sharding.shard_map with per-shard seeds, since pallas_call
    cannot be partitioned by GSPMD) — draw the SR noise inside the
    quantize kernel: no noise tensor, one fewer param-sized HBM round
    trip, zero collectives. Only unevenly-sharded or RTN-mode leaves keep
    the noise+constraint XLA path (controller._use_fused_prng)."""
    qcfg, ocfg, tcfg = cfg.quant, cfg.optimizer, cfg.train

    def train_step(state: Dict[str, Any], batch: Dict[str, Array]
                   ) -> Tuple[Dict[str, Any], Dict[str, Array]]:
        step_key = jax.random.fold_in(state["rng"], state["step"])
        params = state["params"]
        adapt = state["adapt"]

        act_wl = None
        packed = False
        if qcfg.mode != "off":
            qkey = step_key if qcfg.stochastic_rounding else None
            if qcfg.container_dtype == "int8_packed" and \
                    cfg.model.family != "cnn":
                # native-int8 wire format: weights cross the mesh as int8,
                # dequantized inside the scan body after the per-layer
                # gather (§Perf / DESIGN §3)
                packed = True
                qparams = controller.quantize_params_packed(
                    params, adapt, qcfg, qkey, shardings=qparam_shardings)
            else:
                container = {"bfloat16": jnp.bfloat16,
                             "int8": jnp.int8}.get(qcfg.container_dtype,
                                                   jnp.float32)
                qparams = controller.quantize_params(
                    params, adapt, qcfg, qkey, dtype=container,
                    shardings=qparam_shardings)
                if qparam_shardings is not None:
                    qparams = jax.lax.with_sharding_constraint(
                        qparams, qparam_shardings)
            if cfg.model.family != "cnn" and qcfg.quantize_activations:
                act_wl = transformer.act_wl_from_state(adapt)
        else:
            qparams = params

        def loss_fn(qp, mb):
            task, aux = _task_loss(cfg, qp, state["stats"], mb, act_wl)
            if qcfg.mode != "off":
                # reg terms on an eagerly-unpacked view: elementwise +
                # scalar reductions only, so it stays fully sharded (no
                # gathers); its cotangents add onto the same wrefs.
                reg_tree = fxp.unpack_tree(qp) if packed else qp
                full = sparsity.adapt_loss(
                    task, reg_tree, adapt, alpha=ocfg.l1, beta=ocfg.l2,
                    penalty_coef=ocfg.penalty_coef, max_wl=qcfg.max_wl)
            else:
                full = task
            return full, (task, aux)

        grad_fn = jax.value_and_grad(loss_fn, has_aux=True,
                                     allow_int=packed)
        strip = controller.strip_packed_grads if packed else (lambda g: g)

        def compute_grads(qp, b):
            if tcfg.accum_steps > 1:
                # microbatch scan: live activations shrink by accum_steps
                # while the global batch (AdaPT's per-batch semantics) stays.
                mb_batch = _microbatch(b, tcfg.accum_steps)

                def accum_body(carry, mb):
                    g_acc, l_acc, t_acc = carry
                    (loss, (task, aux)), g = grad_fn(qp, mb)
                    g_acc = jax.tree.map(
                        lambda a, x: a + x.astype(a.dtype), g_acc, strip(g))
                    return (g_acc, l_acc + loss, t_acc + task), aux

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, _accum_dtype(tcfg)), params)
                (g, loss, task), auxes = jax.lax.scan(
                    accum_body, (g0, jnp.float32(0.0), jnp.float32(0.0)),
                    mb_batch)
                inv = 1.0 / tcfg.accum_steps
                g = jax.tree.map(lambda x: (x * inv).astype(jnp.float32), g)
                return loss * inv, task * inv, \
                    jax.tree.map(lambda a: a[-1], auxes), g
            (loss, (task, aux)), g = grad_fn(qp, b)
            return loss, task, aux, strip(g)

        if tcfg.qsgd_pod_compression:
            # grads stay pod-local inside a shard_map manual over "pod"
            # (auto over data/model); the cross-pod reduce ships int8 (QSGD)
            # — 4× less traffic on the slowest links (quant/qsgd.py).
            from repro import sharding as shd
            from jax.sharding import PartitionSpec as P
            mesh = shd.current_mesh()
            rules = shd.strip_axes(
                dict(shd._RULES.get()[1]), ("pod",))

            def pod_local(qp, b):
                with shd.use_rules(mesh, rules):
                    loss, task, aux, g = compute_grads(qp, b)
                g = qsgd.psum_compressed(g, step_key, "pod", tcfg.qsgd_bits)
                npods = jax.lax.psum(1, "pod")
                g = jax.tree.map(lambda x: x / npods, g)
                return (jax.lax.pmean(loss, "pod"),
                        jax.lax.pmean(task, "pod"), aux, g)

            loss, task, aux, grads = shd.shard_map(
                pod_local, mesh, axis_names={"pod"},
                in_specs=(P(), P("pod")), out_specs=P(),
                check=False)(qparams, batch)
        else:
            loss, task, aux, grads = compute_grads(qparams, batch)

        if qcfg.mode != "off":
            adapt = controller.accumulate(adapt, grads, task)
            grads = opt_lib.normalize_grads(grads, set(adapt["tensors"]))
        grads = opt_lib.clip_by_global_norm(grads, ocfg.grad_clip)

        opt = opt_lib.rop_update(state["opt"], task, ocfg)
        params, opt = opt_lib.apply_updates(params, grads, opt, ocfg)

        metrics = {"loss": task, "full_loss": loss, "lr": opt["lr"],
                   "grad_norm": _global_norm(grads)}
        if "acc" in aux:
            metrics["acc"] = aux["acc"]
        new_state = {
            "params": params,
            "stats": aux.get("stats", state["stats"]),
            "opt": opt,
            "adapt": adapt,
            "step": state["step"] + 1,
            "rng": state["rng"],
        }
        return new_state, metrics

    return train_step


def _microbatch(batch: Dict[str, Array], accum: int) -> Dict[str, Array]:
    """(B, ...) → (accum, B/accum, ...), microbatch dim sharded like batch."""
    from repro import sharding

    def visit(a):
        mb = a.reshape((accum, a.shape[0] // accum) + a.shape[1:])
        return sharding.shard(mb, None, "batch", *([None] * (a.ndim - 1)))

    return jax.tree.map(visit, batch)


def _accum_dtype(tcfg):
    return jnp.bfloat16 if tcfg.accum_dtype == "bfloat16" else jnp.float32


def _global_norm(grads) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree_util.tree_leaves(grads)))


def make_precision_switch(cfg: Config) -> Callable:
    qcfg = cfg.quant

    def precision_switch(state: Dict[str, Any]) -> Dict[str, Any]:
        adapt = controller.precision_switch(state["adapt"], state["params"],
                                            qcfg)
        return dict(state, adapt=adapt)

    return precision_switch


# ---------------------------------------------------------------------------
# Data dispatch


def make_batch(cfg: Config, step: int) -> Dict[str, Array]:
    if cfg.model.family == "cnn":
        return synthetic.cifar_batch(cfg.model.vocab_size,
                                     cfg.train.global_batch, step,
                                     cfg.train.seed)
    return synthetic.lm_batch(cfg, step)


# ---------------------------------------------------------------------------
# Host-side driver (single-process; the launcher adds mesh/shardings)


def train(cfg: Config, *, steps: Optional[int] = None,
          state: Optional[Dict[str, Any]] = None,
          checkpoint_mgr=None, watchdog=None,
          log: Callable[[str], None] = print,
          telemetry: Optional[list] = None,
          metrics_logger=None, preemption_guard=None,
          heartbeat=None) -> Tuple[Dict[str, Any], list]:
    """Run the loop; returns (state, history). ``telemetry`` (if a list)
    collects per-switch controller snapshots for the paper's perf model;
    ``metrics_logger`` (train.metrics.MetricsLogger) streams JSONL.

    ``preemption_guard`` (fault_tolerance.PreemptionGuard): checked after
    every step — a SIGTERM triggers one final checkpoint save (when a
    ``checkpoint_mgr`` is present) and a clean early return, honoring the
    preempt→final-checkpoint contract INSIDE the loop rather than after
    all ``steps`` complete. ``heartbeat`` (fault_tolerance.Heartbeat)
    emits liveness lines on its own interval."""
    steps = steps if steps is not None else cfg.train.steps
    if state is None:
        state = init_state(cfg)
    step_fn = jax.jit(make_train_step(cfg), donate_argnums=0)
    switch_fn = (jax.jit(make_precision_switch(cfg), donate_argnums=0)
                 if cfg.quant.mode != "off" else None)
    interval = cfg.train.adapt_interval or cfg.quant.lb_lwr

    history = []
    start_step = int(state["step"])
    for i in range(start_step, start_step + steps):
        t0 = time.perf_counter()
        batch = make_batch(cfg, i)
        state, metrics = step_fn(state, batch)
        if switch_fn is not None and (i + 1) % interval == 0:
            state = switch_fn(state)
            if telemetry is not None or metrics_logger is not None:
                snap = controller.snapshot(state["adapt"])
                if telemetry is not None:
                    telemetry.append(snap)
                if metrics_logger is not None:
                    metrics_logger.log_switch(i + 1, snap)
        dt = time.perf_counter() - t0
        if watchdog is not None:
            watchdog.observe(i, dt)
        if (i + 1) % max(cfg.train.log_every, 1) == 0:
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": i + 1, **m, "dt": dt})
            if metrics_logger is not None:
                metrics_logger.log_step(i + 1, m, dt=dt)
            log(f"step {i + 1:5d} loss={m['loss']:.4f} lr={m['lr']:.4g} "
                + (f"acc={m['acc']:.3f} " if "acc" in m else "")
                + f"({dt * 1e3:.0f} ms)")
        if checkpoint_mgr is not None and cfg.train.checkpoint_every and \
                (i + 1) % cfg.train.checkpoint_every == 0:
            checkpoint_mgr.save(state, step=i + 1)
        if heartbeat is not None:
            heartbeat.beat(i + 1, extra=f"loss={float(metrics['loss']):.4f}")
        if preemption_guard is not None and preemption_guard.requested:
            log(f"[preempt] SIGTERM at step {i + 1}: saving final "
                "checkpoint and exiting")
            if checkpoint_mgr is not None:
                checkpoint_mgr.save(state, step=i + 1)
                checkpoint_mgr.wait()
            break
    return state, history
