"""Checkpointing: atomic, optionally async, elastically resumable.

Format: one ``step_<N>/`` directory holding
  * ``arrays.npz``  — flat {path: ndarray} of every leaf in the state pytree
  * ``meta.msgpack``— step, config summary, mesh shape, CRC32 of arrays.npz,
                      treedef repr (for integrity checks)
  * ``DONE``        — commit marker written LAST (rename-based atomicity:
                      a crash mid-write leaves no DONE, restore skips it)

Elastic resume: arrays are restored host-side; the caller re-shards onto
whatever mesh the restoring process has (device count may differ from the
saving run — ZeRO/TP shardings are re-derived from the config, not stored).
"""
from __future__ import annotations

import io
import os
import shutil
import threading
import zlib
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

PyTree = Any

_SEP = "::"


def flatten_state(state: PyTree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            flat[key + "@bf16"] = arr.astype(np.float32)
        else:
            flat[key] = arr
    return flat


def unflatten_into(template: PyTree, flat: Dict[str, np.ndarray]) -> PyTree:
    """Rebuild a state pytree with ``template``'s structure from flat arrays.
    Template leaves provide dtype/sharding targets (elastic resume)."""
    def visit(path, leaf):
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        if key in flat:
            arr = flat[key]
        elif key + "@bf16" in flat:
            arr = flat[key + "@bf16"].astype(jnp.bfloat16)
        else:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = jnp.asarray(arr, dtype=leaf.dtype)
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"state shape {leaf.shape}")
        sharding = getattr(leaf, "sharding", None)
        if sharding is not None and hasattr(sharding, "mesh"):
            arr = jax.device_put(arr, sharding)   # re-shard onto current mesh
        return arr
    return jax.tree_util.tree_map_with_path(visit, template)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, state: PyTree, step: int, extra: Optional[dict] = None):
        flat = flatten_state(state)   # device_get on the caller's thread
        if self.async_save:
            self.wait()               # re-raises a prior async failure
            self._thread = threading.Thread(
                target=self._write_guarded, args=(flat, step, extra or {}),
                daemon=True)
            self._thread.start()
        else:
            self._write(flat, step, extra or {})

    def wait(self):
        """Join the in-flight async save. An exception on the writer thread
        (disk full, permissions, bad path) is captured — not swallowed by
        the daemon thread — and re-raised HERE, so the training loop learns
        its checkpoints are not landing at the next save/wait instead of
        discovering an empty directory after a preemption."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise IOError(f"async checkpoint save failed: {err}") from err

    def _write_guarded(self, flat, step, extra):
        try:
            self._write(flat, step, extra)
        except BaseException as e:      # noqa: BLE001 — report, don't lose
            self._error = e

    def _write(self, flat: Dict[str, np.ndarray], step: int, extra: dict):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        buf = io.BytesIO()
        np.savez(buf, **flat)
        data = buf.getvalue()
        with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
            f.write(data)
        meta = {"step": step, "crc32": zlib.crc32(data),
                "num_arrays": len(flat),
                "device_count": jax.device_count(), **extra}
        with open(os.path.join(tmp, "meta.msgpack"), "wb") as f:
            f.write(msgpack.packb(meta))
        with open(os.path.join(tmp, "DONE"), "w") as f:
            f.write("ok")
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self):
        out = []
        for name in sorted(os.listdir(self.dir)):
            if name.startswith("step_") and not name.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.dir, name, "DONE")):
                out.append(int(name.split("_")[1]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: PyTree, step: Optional[int] = None) -> PyTree:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "arrays.npz"), "rb") as f:
            data = f.read()
        with open(os.path.join(d, "meta.msgpack"), "rb") as f:
            meta = msgpack.unpackb(f.read())
        if zlib.crc32(data) != meta["crc32"]:
            raise IOError(f"checkpoint step {step} failed CRC — torn write?")
        arrays = dict(np.load(io.BytesIO(data)))
        return unflatten_into(template, arrays)

    def restore_meta(self, step: Optional[int] = None) -> dict:
        step = step if step is not None else self.latest_step()
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "meta.msgpack"), "rb") as f:
            return msgpack.unpackb(f.read())
