"""Fault tolerance for long multi-pod runs.

* ``StepWatchdog`` — rolling-median step-time tracker; steps exceeding
  ``straggler_factor ×`` median are logged as straggler events. On real
  multi-host deployments the callback hooks the coordination layer (evict /
  re-shard); here it records and (optionally) raises after repeated stalls.
* ``retry`` — bounded exponential-backoff retry for transient errors
  (preempted hosts, flaky storage).
* ``PreemptionGuard`` — SIGTERM/SIGINT handler that flips a flag the train
  loop polls to write a final checkpoint before exit (standard TPU-pod
  preemption contract).
* ``Heartbeat`` — periodic liveness lines for the cluster supervisor.
"""
from __future__ import annotations

import signal
import statistics
import time
from typing import Callable, List, Optional


class StragglerEvent(RuntimeError):
    pass


class StepWatchdog:
    def __init__(self, factor: float = 3.0, window: int = 50,
                 min_samples: int = 5, max_consecutive: int = 0,
                 on_straggler: Optional[Callable[[int, float, float], None]] = None):
        self.factor = factor
        self.window = window
        self.min_samples = min_samples
        self.max_consecutive = max_consecutive  # 0 = never raise
        self.on_straggler = on_straggler
        self.times: List[float] = []
        self.events: List[dict] = []
        self._consecutive = 0

    def median(self) -> float:
        return statistics.median(self.times) if self.times else 0.0

    def observe(self, step: int, dt: float) -> bool:
        """Record a step time; returns True if it was flagged a straggler."""
        flagged = False
        if len(self.times) >= self.min_samples:
            med = self.median()
            if dt > self.factor * med:
                flagged = True
                self.events.append({"step": step, "dt": dt, "median": med})
                self._consecutive += 1
                if self.on_straggler:
                    self.on_straggler(step, dt, med)
                if self.max_consecutive and \
                        self._consecutive >= self.max_consecutive:
                    raise StragglerEvent(
                        f"{self._consecutive} consecutive straggler steps "
                        f"(last {dt:.3f}s vs median {med:.3f}s)")
        if not flagged:
            self._consecutive = 0
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        return flagged


def retry(fn: Callable, *args, attempts: int = 3, base_delay: float = 0.5,
          exceptions=(IOError, OSError), on_retry=None, **kwargs):
    for i in range(attempts):
        try:
            return fn(*args, **kwargs)
        except exceptions as e:
            if i == attempts - 1:
                raise
            if on_retry:
                on_retry(i, e)
            time.sleep(base_delay * (2 ** i))


class PreemptionGuard:
    """Flips ``requested`` on SIGTERM/SIGINT; context-manager restores the
    previous handlers."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self.signals = signals
        self.requested = False
        self._prev = {}

    def _handler(self, signum, frame):
        self.requested = True

    def __enter__(self):
        for s in self.signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, h in self._prev.items():
            signal.signal(s, h)
        return False


class Heartbeat:
    def __init__(self, interval: float = 30.0, emit: Callable[[str], None] = print):
        self.interval = interval
        self.emit = emit
        self._last = 0.0

    def beat(self, step: int, extra: str = ""):
        now = time.monotonic()
        if now - self._last >= self.interval:
            self._last = now
            self.emit(f"[heartbeat] step={step} t={time.time():.0f} {extra}")
