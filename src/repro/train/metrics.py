"""Training observability: structured JSONL metrics + AdaPT precision
telemetry, suitable for fleet-side scraping (one line per event, flat
schema, monotonically flushed).

    logger = MetricsLogger("runs/exp1")
    logger.log_step(step, {"loss": ..., "lr": ...}, dt=0.42)
    logger.log_switch(step, controller.snapshot(state["adapt"]))
    logger.close()

`wl_summary` condenses a controller snapshot into scalar aggregates the
dashboards care about (mean/min/max WL, nonzero fraction, paper's model-
size units Σ sp·WL) — the full per-tensor arrays go to the switch log.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, Optional

import numpy as np


def wl_summary(snapshot: Dict[str, Dict[str, Any]]) -> Dict[str, float]:
    if not snapshot:
        return {}
    wls = np.concatenate([np.atleast_1d(np.asarray(t["wl"], np.float32))
                          for t in snapshot.values()])
    sps = np.concatenate([np.atleast_1d(np.asarray(t["sp"], np.float32))
                          for t in snapshot.values()])
    return {
        "wl_mean": float(wls.mean()),
        "wl_min": float(wls.min()),
        "wl_max": float(wls.max()),
        "nonzero_mean": float(sps.mean()),
        "size_units": float((wls * sps).sum()),   # paper's sz = Σ sp·WL
        "num_tensors": int(len(snapshot)),
    }


class MetricsLogger:
    def __init__(self, directory: str, run_name: str = "run",
                 flush_every: int = 20):
        os.makedirs(directory, exist_ok=True)
        self.path = os.path.join(directory, f"{run_name}.metrics.jsonl")
        self.switch_path = os.path.join(directory,
                                        f"{run_name}.switches.jsonl")
        self._f = open(self.path, "a", buffering=1)
        self._fs = open(self.switch_path, "a", buffering=1)
        self._n = 0
        self.flush_every = flush_every

    def _emit(self, f, record: Dict[str, Any]):
        record.setdefault("t", time.time())
        f.write(json.dumps(record) + "\n")
        self._n += 1
        if self._n % self.flush_every == 0:
            f.flush()

    def log_step(self, step: int, metrics: Dict[str, Any],
                 dt: Optional[float] = None):
        rec = {"kind": "step", "step": step,
               **{k: float(v) for k, v in metrics.items()}}
        if dt is not None:
            rec["dt_s"] = dt
        self._emit(self._f, rec)

    def log_switch(self, step: int, snapshot: Dict[str, Dict[str, Any]]):
        self._emit(self._fs, {
            "kind": "switch", "step": step, **wl_summary(snapshot),
            "tensors": {k: {"wl": np.asarray(v["wl"]).tolist(),
                            "fl": np.asarray(v["fl"]).tolist(),
                            "sp": np.asarray(v["sp"]).tolist()}
                        for k, v in snapshot.items()},
        })

    def log_event(self, kind: str, **fields):
        self._emit(self._f, {"kind": kind, **fields})

    def close(self):
        self._f.flush()
        self._f.close()
        self._fs.flush()
        self._fs.close()


def read_jsonl(path: str):
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]
