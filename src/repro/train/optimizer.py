"""Optimizers: ASGD (the paper's AdaPT-SGD), plain SGD, Adam (ablation), with
the paper's reduce-on-plateau (ROP) scheduler as jit-safe state.

ASGD = SGD where (paper §3.3/§3.4):
  * gradients of quantized tensors are L2-normalized per tensor
    ("we normalize gradients to limit weight growth and reduce chances of
    weights becoming unrepresentable after an update step"),
  * the loss already carries L1/L2/P regularizers (see core/sparsity.py).

The learning rate lives in the optimizer state (a traced scalar), so ROP
reductions never recompile the step.
"""
from __future__ import annotations

from typing import Any, Dict, Set, Tuple

import jax
import jax.numpy as jnp

from repro.config import OptimizerConfig

Array = jax.Array
PyTree = Any


def init_opt_state(params: PyTree, ocfg: OptimizerConfig) -> Dict[str, Any]:
    state: Dict[str, Any] = {
        "lr": jnp.float32(ocfg.lr),
        "step": jnp.int32(0),
        "rop_best": jnp.float32(jnp.inf),
        "rop_bad": jnp.int32(0),
    }
    if ocfg.name == "adam":
        state["m"] = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        state["v"] = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    elif ocfg.momentum > 0.0:
        state["mom"] = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return state


def _normalize(g: Array) -> Array:
    n = jnp.sqrt(jnp.sum(jnp.square(g.astype(jnp.float32))))
    return (g / jnp.maximum(n, 1e-12)).astype(g.dtype)


def normalize_grads(grads: PyTree, quantized_paths: Set[str]) -> PyTree:
    """Per-tensor L2 normalization on AdaPT-quantized tensors (paper §3.3)."""
    from repro.core.controller import path_str

    def visit(path, g):
        return _normalize(g) if path_str(path) in quantized_paths else g

    return jax.tree_util.tree_map_with_path(visit, grads)


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    if max_norm <= 0:
        return grads
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree_util.tree_leaves(grads))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(jnp.sqrt(sq), 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads)


def apply_updates(params: PyTree, grads: PyTree, state: Dict[str, Any],
                  ocfg: OptimizerConfig) -> Tuple[PyTree, Dict[str, Any]]:
    lr = state["lr"]
    step = state["step"] + 1
    new_state = dict(state, step=step)
    if ocfg.name == "adam":
        b1, b2, eps = ocfg.beta1, ocfg.beta2, ocfg.adam_eps
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        t = step.astype(jnp.float32)
        corr = jnp.sqrt(1 - b2 ** t) / (1 - b1 ** t)
        upd = jax.tree.map(lambda m_, v_: corr * m_ / (jnp.sqrt(v_) + eps), m, v)
        new_state.update(m=m, v=v)
    elif ocfg.momentum > 0.0:
        mom = jax.tree.map(
            lambda mo, g: ocfg.momentum * mo + g.astype(jnp.float32),
            state["mom"], grads)
        upd = mom
        new_state["mom"] = mom
    else:
        upd = grads
    params = jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) - lr * u.astype(jnp.float32)
                      ).astype(p.dtype), params, upd)
    return params, new_state


def rop_update(state: Dict[str, Any], loss: Array,
               ocfg: OptimizerConfig) -> Dict[str, Any]:
    """Reduce-on-plateau: lr *= factor after `patience` steps without a
    `threshold` improvement (paper §4.1 uses torch's ReduceLROnPlateau)."""
    improved = loss < state["rop_best"] - ocfg.rop_threshold
    best = jnp.minimum(state["rop_best"], loss)
    bad = jnp.where(improved, 0, state["rop_bad"] + 1)
    reduce_now = bad >= ocfg.rop_patience
    lr = jnp.where(reduce_now, state["lr"] * ocfg.rop_factor, state["lr"])
    bad = jnp.where(reduce_now, 0, bad)
    return dict(state, lr=lr, rop_best=best, rop_bad=bad)
