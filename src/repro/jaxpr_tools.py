"""Tiny jaxpr structure readers shared by the structural tests and the
quant microbenchmark — the fast-path perf claims ("no materialized noise
operand", "no scatter-add histograms", "≤2 param-sized kernel operands")
are read off the traced program, so they hold on any backend.
"""
from __future__ import annotations

from jax.core import ClosedJaxpr, Jaxpr

# RNG primitives whose param-sized outputs would mean a materialized
# noise tensor (jax.random.uniform lowers to these under jit).
RNG_PRIMS = ("threefry", "random_bits", "random_seed", "random_wrap")


def subjaxprs(v):
    """All jaxprs nested inside one eqn-params value."""
    if isinstance(v, ClosedJaxpr):
        return [v.jaxpr]
    if isinstance(v, Jaxpr):
        return [v]
    if isinstance(v, (list, tuple)):
        return [s for x in v for s in subjaxprs(x)]
    return []


def iter_eqns(jaxpr):
    """Depth-first over every eqn, descending into sub-jaxprs (scan/cond/
    pjit/custom_vjp bodies and anything else carried in eqn params)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in subjaxprs(v):
                yield from iter_eqns(sub)


def rng_eqns_of_size(jaxpr, min_size: int):
    """RNG eqns producing an output of ≥ min_size elements."""
    return [eqn for eqn in iter_eqns(jaxpr)
            if any(r in eqn.primitive.name for r in RNG_PRIMS)
            and any(getattr(ov.aval, "size", 0) >= min_size
                    for ov in eqn.outvars)]


def count_primitives(jaxpr, name_substr: str) -> int:
    return sum(name_substr in eqn.primitive.name for eqn in iter_eqns(jaxpr))


# Gather-shaped collectives whose param-sized outputs would mean the f32
# master (or its quantized copy) is being reassembled across the mesh —
# exactly what the shard_map-wrapped quantize exists to prevent. psum/
# pmean are deliberately absent: scalar reductions are fine.
COLLECTIVE_PRIMS = ("all_gather", "all_to_all")


def collective_eqns_of_size(jaxpr, min_size: int):
    """Gather-type collective eqns producing an output of ≥ min_size
    elements (descends into shard_map/pjit bodies via iter_eqns)."""
    return [eqn for eqn in iter_eqns(jaxpr)
            if any(p in eqn.primitive.name for p in COLLECTIVE_PRIMS)
            and any(getattr(ov.aval, "size", 0) >= min_size
                    for ov in eqn.outvars)]
