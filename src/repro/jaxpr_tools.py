"""Tiny jaxpr structure readers shared by the structural tests and the
quant microbenchmark — the fast-path perf claims ("no materialized noise
operand", "no scatter-add histograms", "≤2 param-sized kernel operands")
are read off the traced program, so they hold on any backend.
"""
from __future__ import annotations

from jax.core import ClosedJaxpr, Jaxpr

# RNG primitives whose param-sized outputs would mean a materialized
# noise tensor (jax.random.uniform lowers to these under jit).
RNG_PRIMS = ("threefry", "random_bits", "random_seed", "random_wrap")


def subjaxprs(v):
    """All jaxprs nested inside one eqn-params value."""
    if isinstance(v, ClosedJaxpr):
        return [v.jaxpr]
    if isinstance(v, Jaxpr):
        return [v]
    if isinstance(v, (list, tuple)):
        return [s for x in v for s in subjaxprs(x)]
    return []


def iter_eqns(jaxpr):
    """Depth-first over every eqn, descending into sub-jaxprs (scan/cond/
    pjit/custom_vjp bodies and anything else carried in eqn params)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in subjaxprs(v):
                yield from iter_eqns(sub)


def rng_eqns_of_size(jaxpr, min_size: int):
    """RNG eqns producing an output of ≥ min_size elements."""
    return [eqn for eqn in iter_eqns(jaxpr)
            if any(r in eqn.primitive.name for r in RNG_PRIMS)
            and any(getattr(ov.aval, "size", 0) >= min_size
                    for ov in eqn.outvars)]


def count_primitives(jaxpr, name_substr: str) -> int:
    return sum(name_substr in eqn.primitive.name for eqn in iter_eqns(jaxpr))


def pallas_eqns(jaxpr):
    """Every pallas_call eqn, descending into scan/cond/pjit/custom-vjp
    bodies — the raw material for "no silent XLA fallback" assertions."""
    return [eqn for eqn in iter_eqns(jaxpr)
            if eqn.primitive.name == "pallas_call"]


def pallas_kernel_names(jaxpr):
    """Best-effort kernel-function name per pallas_call eqn (e.g.
    '_flash_kernel', '_flash_dq_kernel'), read from the eqn's
    name_and_src_info (newer JAX) or name param."""
    names = []
    for eqn in pallas_eqns(jaxpr):
        info = eqn.params.get("name_and_src_info")
        name = getattr(info, "name", None) or eqn.params.get("name") or ""
        names.append(name)
    return names


def count_pallas_calls(jaxpr, name_substr: str = "") -> int:
    """pallas_call eqns whose kernel name contains ``name_substr`` ('' =
    all). The structural contract behind quant.use_pallas: the jitted,
    DIFFERENTIATED forward must contain the expected forward and backward
    kernels — a silent fallback to XLA shows up here as a zero."""
    return sum(name_substr in n for n in pallas_kernel_names(jaxpr))


def pallas_grids(jaxpr):
    """Grid tuple per pallas_call eqn (same order as ``pallas_eqns``).
    Backs the VMEM-boundedness assertions: a tail-masked kernel on a
    prime dim must show a MULTI-block grid (``pl.cdiv`` of the clamp),
    never a whole-dim single block."""
    return [tuple(eqn.params["grid_mapping"].grid)
            for eqn in pallas_eqns(jaxpr)]


def pallas_block_shapes(jaxpr):
    """Per pallas_call eqn, each operand's block shape (inputs then
    outputs, same order as ``pallas_eqns``). With tail masking the chosen
    block must equal min(requested, dim) — reading it off the traced
    program pins the no-whole-dim-fallback contract on any backend."""
    return [[tuple(bm.block_shape) for bm in
             eqn.params["grid_mapping"].block_mappings]
            for eqn in pallas_eqns(jaxpr)]


def iter_xla_eqns(jaxpr):
    """Like ``iter_eqns`` but does NOT descend into pallas_call bodies —
    the view of what XLA itself executes (a kernel's in-register
    dot_general on a whole-dim block is not an XLA matmul)."""
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name == "pallas_call":
            continue
        for v in eqn.params.values():
            for sub in subjaxprs(v):
                yield from iter_xla_eqns(sub)


def dot_general_shapes(jaxpr):
    """(lhs shape, rhs shape, rhs dtype) per XLA dot_general eqn
    (descending into scan/cond/pjit/custom-vjp bodies but not into Pallas
    kernels). Backs the dense-path contract: with the fxp kernels wired
    into models/common.dense, NO dot_general in the differentiated train
    step may consume a float operand of a dense weight's shape — a
    dequantized HBM weight copy shows up here as a (K, N)-shaped f32/bf16
    rhs (tests/test_dense_path.py)."""
    out = []
    for eqn in iter_xla_eqns(jaxpr):
        if eqn.primitive.name != "dot_general":
            continue
        lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
        out.append((tuple(lhs.shape), tuple(rhs.shape), rhs.dtype))
    return out


# Gather-shaped collectives whose param-sized outputs would mean the f32
# master (or its quantized copy) is being reassembled across the mesh —
# exactly what the shard_map-wrapped quantize exists to prevent. psum/
# pmean are deliberately absent: scalar reductions are fine.
COLLECTIVE_PRIMS = ("all_gather", "all_to_all")


def collective_eqns_of_size(jaxpr, min_size: int):
    """Gather-type collective eqns producing an output of ≥ min_size
    elements (descends into shard_map/pjit bodies via iter_eqns)."""
    return [eqn for eqn in iter_eqns(jaxpr)
            if any(p in eqn.primitive.name for p in COLLECTIVE_PRIMS)
            and any(getattr(ov.aval, "size", 0) >= min_size
                    for ov in eqn.outvars)]
