"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch tiny --steps 50
    PYTHONPATH=src python -m repro.launch.train --arch granite-8b \
        --shape train_4k --override quant.mode=simulate --dry-steps 3

On a real TPU pod this process runs per host (jax.distributed.initialize is
called when the coordinator env vars are present); in this container it runs
single-process on CPU. Fault-tolerance wiring: checkpoint manager with
atomic resume, preemption guard, step watchdog.
"""
from __future__ import annotations

import argparse
import os

import jax

from repro.config import load_config
from repro.train import train_loop
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import (Heartbeat, PreemptionGuard,
                                         StepWatchdog)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--metrics-dir", default="",
                    help="write JSONL step/switch telemetry here")
    ap.add_argument("--override", action="append", default=[])
    args = ap.parse_args(argv)

    if "COORDINATOR_ADDRESS" in os.environ:   # multi-host entry
        jax.distributed.initialize()

    if args.smoke:
        from repro.configs import get_smoke_config
        from repro.config import apply_overrides, with_shape
        cfg = get_smoke_config(args.arch)
        if args.shape:
            cfg = with_shape(cfg, args.shape)
        cfg = apply_overrides(cfg, args.override)
    else:
        cfg = load_config(args.arch, args.shape, overrides=args.override)

    state = None
    mgr = None
    if args.checkpoint_dir:
        mgr = CheckpointManager(args.checkpoint_dir,
                                keep=cfg.train.keep_checkpoints,
                                async_save=cfg.train.async_checkpoint)
        if args.resume and mgr.latest_step() is not None:
            template = train_loop.init_state(cfg)
            state = mgr.restore(template)
            print(f"[train] resumed from step {int(state['step'])}")

    watchdog = StepWatchdog(factor=cfg.train.straggler_factor,
                            on_straggler=lambda s, dt, med: print(
                                f"[watchdog] straggler step {s}: "
                                f"{dt:.2f}s vs median {med:.2f}s"))

    metrics_logger = None
    if args.metrics_dir:
        from repro.train.metrics import MetricsLogger
        metrics_logger = MetricsLogger(args.metrics_dir,
                                       run_name=args.arch.replace("/", "_"))

    telemetry: list = []
    # the guard + heartbeat are wired INTO the loop: SIGTERM mid-run saves
    # a final checkpoint at the interrupted step and returns early, rather
    # than being noticed only after all steps complete
    with PreemptionGuard() as guard:
        state, history = train_loop.train(
            cfg, steps=args.steps, state=state, checkpoint_mgr=mgr,
            watchdog=watchdog, telemetry=telemetry,
            metrics_logger=metrics_logger, preemption_guard=guard,
            heartbeat=Heartbeat())
    if metrics_logger is not None:
        metrics_logger.log_event("finished", steps=int(state["step"]))
        metrics_logger.close()
    if mgr is not None:
        mgr.save(state, step=int(state["step"]))
        mgr.wait()
    if history:
        print(f"[train] done: step={history[-1]['step']} "
              f"loss={history[-1]['loss']:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
