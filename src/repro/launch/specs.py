"""ShapeDtypeStruct stand-ins for every model input — the dry-run's fuel.

``jax.eval_shape`` over the real init/data functions guarantees the specs
can never drift from the actual runtime shapes, and allocates nothing (the
full configs reach 480B parameters).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import Config, shape_kind
from repro.data import synthetic
from repro.models import transformer
from repro.train import train_loop

Array = jax.Array


def state_specs(cfg: Config):
    return jax.eval_shape(lambda: train_loop.init_state(cfg))


def batch_specs(cfg: Config):
    return jax.eval_shape(lambda: train_loop.make_batch(cfg, 0))


def param_specs(cfg: Config):
    return jax.eval_shape(
        lambda: transformer.init_params(jax.random.PRNGKey(0), cfg.model))


def decode_specs(cfg: Config) -> Dict[str, Any]:
    """Inputs of one serve decode step: token batch + caches at seq_len."""
    m, t = cfg.model, cfg.train
    caches = jax.eval_shape(
        lambda: transformer.init_caches(m, t.global_batch, t.seq_len))
    return {
        "qparams": param_specs(cfg),
        "token": jax.ShapeDtypeStruct((t.global_batch,), jnp.int32),
        "caches": caches,
        "t": jax.ShapeDtypeStruct((), jnp.int32),
    }


def prefill_specs(cfg: Config) -> Dict[str, Any]:
    m, t = cfg.model, cfg.train
    out: Dict[str, Any] = {"qparams": param_specs(cfg)}
    if m.is_encoder:
        out["embeds"] = jax.ShapeDtypeStruct(
            (t.global_batch, t.seq_len, m.d_model), jnp.float32)
    else:
        out["tokens"] = jax.ShapeDtypeStruct(
            (t.global_batch, t.seq_len), jnp.int32)
    if m.cross_attn_every:
        out["memory"] = jax.ShapeDtypeStruct(
            (t.global_batch, m.num_image_tokens, m.d_model), jnp.float32)
    return out


def input_specs(cfg: Config) -> Tuple[Any, ...]:
    """The (architecture × input-shape) cell's full input pytree, per kind."""
    kind = shape_kind(cfg.shape)
    if kind == "train":
        return (state_specs(cfg), batch_specs(cfg))
    if kind == "prefill":
        return (prefill_specs(cfg),)
    return (decode_specs(cfg),)   # decode / long-context decode


def cell_is_runnable(cfg: Config) -> Tuple[bool, str]:
    """Shape-applicability rules (DESIGN.md §4): returns (runnable, reason)."""
    kind = shape_kind(cfg.shape)
    m = cfg.model
    if m.is_encoder and kind in ("decode",):
        return False, "encoder-only: no decode step"
    if cfg.shape == "long_500k" and not m.supports_long_context:
        return False, "full quadratic attention at 500k ctx"
    return True, ""
