"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes and report memory / cost / collective analysis.

MUST set the placeholder-device flag before any other import touches jax —
jax locks the device count on first backend initialization.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax           # noqa: E402

from repro import sharding                      # noqa: E402
from repro.config import SHAPES, load_config, shape_kind  # noqa: E402
from repro.configs import assigned_archs        # noqa: E402
from repro.launch import mesh as mesh_lib       # noqa: E402
from repro.launch import specs as specs_lib     # noqa: E402
from repro.serve import engine as engine_lib    # noqa: E402
from repro.train import train_loop              # noqa: E402


def _rules_kind(shape: str) -> str:
    return "long" if shape == "long_500k" else shape_kind(shape)


def lower_cell(arch: str, shape: str, *, multi_pod: bool = False,
               do_compile: bool = True, overrides=None) -> Dict[str, Any]:
    """Lower (and compile) one cell; returns the §Dry-run/§Roofline record."""
    cfg = load_config(arch, shape, overrides=overrides)
    runnable, reason = specs_lib.cell_is_runnable(cfg)
    if not runnable:
        return {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                "status": "skipped", "reason": reason}

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    kind = shape_kind(shape)
    rules = mesh_lib.make_rules(cfg, mesh, _rules_kind(shape))
    if cfg.quant.container_dtype == "int8_packed" and kind == "train":
        rules["#packed_slice_specs"] = mesh_lib.packed_slice_specs(
            specs_lib.param_specs(cfg), cfg, mesh)
    t0 = time.time()

    with sharding.use_rules(mesh, rules):
        if kind == "train":
            state_sh = mesh_lib.state_shardings(
                specs_lib.state_specs(cfg), cfg, mesh)
            batch_sh = mesh_lib.batch_shardings(
                specs_lib.batch_specs(cfg), mesh)
            fn = train_loop.make_train_step(
                cfg, qparam_shardings=state_sh["params"])
            jfn = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                          out_shardings=(state_sh, None))
            lowered = jfn.lower(specs_lib.state_specs(cfg),
                                specs_lib.batch_specs(cfg))
        elif kind == "prefill":
            sp = specs_lib.prefill_specs(cfg)
            qsh = mesh_lib.param_shardings(sp["qparams"], cfg, mesh)
            dsh = mesh_lib.batch_shardings(
                {k: v for k, v in sp.items() if k != "qparams"}, mesh)
            m = cfg.model
            if m.is_encoder:
                from repro.models import transformer

                def fn(qparams, embeds):
                    return transformer.forward(qparams, m, embeds=embeds)
                jfn = jax.jit(fn, in_shardings=(qsh, dsh["embeds"]))
                lowered = jfn.lower(sp["qparams"], sp["embeds"])
            else:
                pf = engine_lib.make_prefill(cfg)
                args = [sp["qparams"], sp["tokens"]]
                in_sh = [qsh, dsh["tokens"]]
                if "memory" in sp:
                    args.append(sp["memory"])
                    in_sh.append(dsh["memory"])
                jfn = jax.jit(pf, in_shardings=tuple(in_sh))
                lowered = jfn.lower(*args)
        else:  # decode / long-context decode
            sp = specs_lib.decode_specs(cfg)
            qsh = mesh_lib.param_shardings(sp["qparams"], cfg, mesh)
            csh = mesh_lib.cache_shardings(sp["caches"], cfg, mesh,
                                           _rules_kind(shape))
            tsh = mesh_lib.batch_shardings(
                {"token": sp["token"]}, mesh,
                kind)["token"] if shape != "long_500k" else \
                mesh_lib.replicated(mesh)
            fn = engine_lib.make_decode(cfg)
            jfn = jax.jit(fn, in_shardings=(
                qsh, tsh, csh, mesh_lib.replicated(mesh)),
                out_shardings=(None, csh))
            lowered = jfn.lower(sp["qparams"], sp["token"], sp["caches"],
                                sp["t"])

    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape, "multi_pod": multi_pod,
        "status": "lowered", "lower_s": round(time.time() - t0, 1),
        "devices": mesh.devices.size, "kind": kind,
    }
    if do_compile:
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        rec["status"] = "compiled"
        mem = compiled.memory_analysis()
        if mem is not None:
            rec["memory"] = {
                "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                "peak_bytes": int(getattr(mem, "peak_memory_in_bytes", 0) or
                                  getattr(mem, "temp_size_in_bytes", 0)),
            }
        from repro.roofline import hlo_costs
        c = hlo_costs.xla_cost_analysis(compiled)
        if c:
            # NB: XLA counts while bodies once — kept for reference only;
            # the roofline uses the trip-count-aware walker below.
            rec["xla_cost_analysis"] = {
                k: float(v) for k, v in c.items()
                if isinstance(v, (int, float)) and
                k in ("flops", "bytes accessed", "transcendentals")}
        walked = hlo_costs.module_costs(compiled.as_text())
        rec["cost"] = {"flops": walked["flops"],
                       "bytes accessed": walked["bytes"]}
        rec["collectives"] = walked["collectives"]
        rec["dynamic_loops"] = walked["dynamic_loops"]
    return rec


def run_cells(archs, shapes, *, multi_pod: bool, do_compile: bool,
              out_dir: Optional[str], overrides=None):
    results = []
    for arch in archs:
        for shape in shapes:
            tag = f"{arch} × {shape} × {'2pod' if multi_pod else '1pod'}"
            try:
                rec = lower_cell(arch, shape, multi_pod=multi_pod,
                                 do_compile=do_compile, overrides=overrides)
                status = rec["status"]
                extra = rec.get("reason", "")
                if "cost" in rec:
                    extra = (f"flops={rec['cost'].get('flops', 0):.3e} "
                             f"compile={rec.get('compile_s')}s")
                print(f"[dryrun] {tag}: {status} {extra}", flush=True)
            except Exception as e:  # a failed cell is a bug — record & move on
                rec = {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                       "status": "FAILED", "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
                print(f"[dryrun] {tag}: FAILED {e}", flush=True)
            results.append(rec)
            if out_dir:
                os.makedirs(out_dir, exist_ok=True)
                name = (f"{arch}_{shape}_{'2pod' if multi_pod else '1pod'}"
                        .replace("/", "_").replace(".", "_"))
                with open(os.path.join(out_dir, name + ".json"), "w") as f:
                    json.dump(rec, f, indent=1)
    return results


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--override", action="append", default=[],
                    help="dotted config overrides, e.g. quant.mode=off")
    args = ap.parse_args(argv)

    archs = assigned_archs() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    all_results = []
    for mp in meshes:
        all_results += run_cells(archs, shapes, multi_pod=mp,
                                 do_compile=not args.no_compile,
                                 out_dir=args.out, overrides=args.override)
    failed = [r for r in all_results if r["status"] == "FAILED"]
    print(f"\n[dryrun] {len(all_results)} cells: "
          f"{sum(r['status'] == 'compiled' for r in all_results)} compiled, "
          f"{sum(r['status'] == 'skipped' for r in all_results)} skipped, "
          f"{len(failed)} failed")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
