"""Production mesh + logical-axis rule sets + parameter shardings.

Mesh (per task spec):
    single-pod: (16, 16)      axes ("data", "model")        — 256 chips
    multi-pod:  (2, 16, 16)   axes ("pod", "data", "model") — 512 chips

Rule sets map the model code's logical axes (see repro/sharding.py) to mesh
axes per input-shape kind:
    train / prefill / decode: batch→(pod,data), heads/ff/experts/vocab→model
    long-context decode (batch=1): the KV-cache *sequence* axis takes the
    data axis instead (you cannot shard a batch of 1).

Parameter shardings are name-based (megatron TP): column-parallel in-proj,
row-parallel out-proj, vocab-sharded embedding/head, expert-parallel MoE.
Tensors bigger than ``FSDP_THRESHOLD`` elements additionally fold the data
axis into a free dimension (2-D weight sharding) — without this the ≥100B
configs (arctic-480b, mixtral-8x22b) cannot fit HBM; XLA re-gathers one
scanned layer at a time inside the loop, which is exactly the FSDP schedule.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import Config

# elements; ~256 MiB in bf16. Above this a weight also shards over "data".
FSDP_THRESHOLD = 128 * 1024 * 1024


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_cpu_mesh() -> Mesh:
    """1-device mesh with the same axis names (tests / local smoke)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_rules(cfg: Config, mesh: Mesh, kind: str) -> Dict[str, tuple]:
    """Logical→physical rules for activations inside the model code."""
    dp = dp_axes(mesh)
    e = cfg.model.num_experts
    msize = mesh.shape["model"]
    expert_parallel = e > 0 and e % msize == 0
    heads_ok = _div(cfg.model.num_heads, msize)
    ssa = cfg.mesh.seq_shard_attn
    q_seq = ("model",) if (ssa == "on" or (ssa == "auto" and not heads_ok)) \
        else ()
    pad_heads = 0
    if ssa == "pad" and not heads_ok:
        # pad q/k/v heads up to the next model-axis multiple inside the
        # attention einsums: ≤(pad/H) extra FLOPs, but fully head-sharded —
        # avoids both replication AND the q-seq resharding cliffs (§Perf).
        pad_heads = ((cfg.model.num_heads + msize - 1) // msize) * msize
        q_seq = ()
    rules = {
        "batch": dp,
        "seq": (),
        "q_seq": q_seq,
        "heads": ("model",) if (heads_ok or pad_heads) else (),
        "#pad_heads_to": pad_heads or None,
        "kv_heads": ("model",) if _div(cfg.model.num_kv_heads, msize) else (),
        "ff": () if expert_parallel else ("model",),
        "experts": ("model",) if expert_parallel else (),
        "vocab": ("model",),
        "embed": (),
    }
    rules.setdefault("kv_seq", ())
    if kind == "decode" and cfg.mesh.decode_kv_shard == "seq" and \
            not _div(cfg.model.num_kv_heads, msize):
        # split-KV decode: cache sequence carries the model axis; heads
        # stay local (only softmax stats / 1-token outputs cross chips)
        rules["kv_seq"] = ("model",)
        rules["heads"] = ()
    if kind == "long":
        # batch=1: shard the KV/sequence axis over data instead
        rules["batch"] = ()
        rules["kv_seq"] = dp
    if cfg.train.tp_reduce_dtype == "bfloat16":
        rules["#tp_reduce_bf16"] = True
    return rules


def _div(n: int, k: int) -> bool:
    return n > 0 and n % k == 0


# ---------------------------------------------------------------------------
# Parameter shardings (name-based)


def _fits(shape, dim: int, n: int) -> bool:
    return shape[dim] % n == 0 and shape[dim] >= n


def param_pspec(path: str, shape: Tuple[int, ...], cfg: Config, mesh: Mesh,
                *, fsdp: Optional[bool] = None) -> P:
    """PartitionSpec for one parameter tensor.

    ``fsdp=None`` folds the data axis in automatically for huge tensors;
    True/False force it (the ZeRO master-shard flag / dry-run ablations).
    """
    msize = mesh.shape["model"]
    dsize = mesh.shape["data"]
    name = path.split("/")[-1]
    parts: list = [None] * len(shape)

    def col(dim):   # shard output/column dim over model
        if _fits(shape, dim, msize):
            parts[dim] = "model"

    e = cfg.model.num_experts
    expert_parallel = e > 0 and e % msize == 0

    if name == "embed":
        col(0)                                   # vocab rows
    elif name == "head":
        col(len(shape) - 1)                      # vocab cols
    elif name in ("wk", "wv"):
        # when kv heads don't divide the TP degree the (S, hkv·dh)→
        # (S, hkv, dh) reshape cannot keep a col-sharding and the K/V
        # activations get all-gathered every layer (~30 GiB/step on
        # granite-8b, kv=8 on 16-way — §Perf h3/h4). kv_proj="replicate"
        # keeps the small wk/wv replicated instead (no gathers, redundant
        # kv-proj compute).
        if _fits((cfg.model.num_kv_heads,), 0, msize) or \
                cfg.mesh.kv_proj != "replicate":
            col(len(shape) - 1)
    elif name in ("wq", "wi_gate", "wi_up", "in_proj"):
        col(len(shape) - 1)
    elif name in ("wo", "out_proj"):
        col(len(shape) - 2)                      # row-parallel (contraction)
    elif name == "conv_w":
        col(len(shape) - 1)                      # depthwise channels
    elif name in ("we_gate", "we_up", "we_down"):
        edim = len(shape) - 3
        if expert_parallel:
            parts[edim] = "model"
        else:                                    # TP inside each expert
            fdim = (len(shape) - 1 if name != "we_down" else len(shape) - 2)
            col(fdim)
    elif name == "router" or len(shape) < 2:
        pass                                     # replicated
    elif name == "w" and len(shape) == 4:
        pass                                     # conv kernels (CNN): DP only
    elif name == "w":
        col(len(shape) - 1)

    size = int(np.prod(shape))
    want_fsdp = fsdp if fsdp is not None else size >= FSDP_THRESHOLD
    if want_fsdp:
        for dim in range(len(shape) - 1, -1, -1):
            if parts[dim] is None and _fits(shape, dim, dsize) and \
                    shape[dim] >= dsize:
                parts[dim] = "data"
                break
    return P(*parts)


def state_shardings(state_shapes, cfg: Config, mesh: Mesh, *,
                    zero: Optional[bool] = None):
    """NamedShardings for the full train-state pytree (params + opt + adapt).

    ``zero`` controls data-axis folding for master/opt/adapt tensors
    (defaults to cfg.train.zero_shard or automatic-by-size)."""
    from repro.core.controller import path_str
    if zero is None:
        zero = {"auto": None, "on": True, "off": False}.get(
            cfg.train.fsdp, None)
        if cfg.train.zero_shard:
            zero = True

    def visit(path, leaf):
        p = path_str(path)
        shape = leaf.shape
        if not shape:
            return NamedSharding(mesh, P())
        if p.startswith("params/") or p.startswith("stats/"):
            spec = param_pspec(p.split("/", 1)[1], shape, cfg, mesh, fsdp=zero)
        elif p.startswith("opt/m/") or p.startswith("opt/v/") or \
                p.startswith("opt/mom/"):
            spec = param_pspec(p.split("/", 2)[2], shape, cfg, mesh, fsdp=zero)
        elif p.startswith("adapt/tensors/") and p.endswith("/grad_sum"):
            tensor_path = p[len("adapt/tensors/"):-len("/grad_sum")]
            spec = param_pspec(tensor_path, shape, cfg, mesh, fsdp=zero)
        else:
            spec = P()
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(visit, state_shapes)


def packed_slice_specs(param_shapes, cfg: Config, mesh: Mesh) -> Dict:
    """TP-only NamedShardings for the PER-PERIOD slice of each stacked
    weight (leading period dim dropped) + full specs for unstacked tensors.
    Consumed by fxp.unpack_tree via the '#packed_slice_specs' rules flag to
    pin int8 weight gathers (see that docstring)."""
    from repro.core.controller import is_stacked, path_str
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(param_shapes)[0]:
        p = path_str(path)
        if len(leaf.shape) < 2:
            continue
        if is_stacked(p) and len(leaf.shape) >= 3:
            spec = param_pspec(p, leaf.shape[1:], cfg, mesh, fsdp=False)
            key = p.split("/", 1)[1]          # body sees paths sans "blocks/"
        else:
            spec = param_pspec(p, leaf.shape, cfg, mesh, fsdp=False)
            key = p
        out[key] = NamedSharding(mesh, spec)
    return out


def param_shardings(param_shapes, cfg: Config, mesh: Mesh, *,
                    fsdp: Optional[bool] = None):
    """NamedShardings for a bare parameter pytree (serving / dry-run)."""
    from repro.core.controller import path_str

    def visit(path, leaf):
        if not leaf.shape:
            return NamedSharding(mesh, P())
        return NamedSharding(
            mesh, param_pspec(path_str(path), leaf.shape, cfg, mesh, fsdp=fsdp))

    return jax.tree_util.tree_map_with_path(visit, param_shapes)


def batch_shardings(batch_shapes, mesh: Mesh, kind: str = "train"):
    dp = dp_axes(mesh)
    spec_dp = dp if len(dp) > 1 else (dp[0] if dp else None)

    def visit(leaf):
        parts = [None] * len(leaf.shape)
        if parts:
            parts[0] = spec_dp
        return NamedSharding(mesh, P(*parts))

    return jax.tree.map(visit, batch_shapes)


def cache_shardings(cache_shapes, cfg: Config, mesh: Mesh, kind: str):
    """Decode caches: (NP, B, C, H, D) — batch over data (decode) or cache
    seq over data (long, batch=1); kv heads over model when divisible."""
    msize = mesh.shape["model"]
    dp = dp_axes(mesh)
    spec_dp = dp if len(dp) > 1 else (dp[0] if dp else None)

    split_kv = cfg.mesh.decode_kv_shard == "seq"

    def visit(path, leaf):
        name = str(getattr(path[-1], "key", ""))
        shape = leaf.shape
        parts: list = [None] * len(shape)
        if name in ("k", "v") and len(shape) == 5:
            NPd, B, C, H, D = shape
            if kind == "long" and B == 1:
                if C % max(_n(dp_size(mesh)), 1) == 0:
                    parts[2] = spec_dp
            else:
                parts[1] = spec_dp
            if H % msize == 0:
                parts[3] = "model"
            elif split_kv and C % msize == 0:
                # split-KV decode: kv heads can't shard → shard the cache
                # sequence over model; attention reduces per-head softmax
                # stats instead of gathering the cache (§Perf lever)
                parts[2] = "model"
        elif name == "conv" and len(shape) == 4:     # (NP,B,K,C)
            if kind != "long":
                parts[1] = spec_dp
            if shape[3] % msize == 0:
                parts[3] = "model"
        elif name == "ssm" and len(shape) == 5:      # (NP,B,H,P,N)
            if kind != "long":
                parts[1] = spec_dp
            if shape[2] % msize == 0:
                parts[2] = "model"
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map_with_path(visit, cache_shapes)


def dp_size(mesh: Mesh) -> int:
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def _n(x: int) -> int:
    return x


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
