"""Serving launcher: load a checkpoint (or fresh init), quantize once at the
AdaPT controller's final ⟨WL,FL⟩, and serve batched generation requests.

    PYTHONPATH=src python -m repro.launch.serve --arch tiny --tokens 16 \
        --batch 4 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.config import load_config
from repro.serve.engine import Engine
from repro.train import train_loop
from repro.train.checkpoint import CheckpointManager


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--override", action="append", default=[])
    args = ap.parse_args(argv)

    if args.smoke:
        from repro.configs import get_smoke_config
        from repro.config import apply_overrides
        cfg = apply_overrides(get_smoke_config(args.arch), args.override)
    else:
        cfg = load_config(args.arch, overrides=args.override)

    state = train_loop.init_state(cfg)
    if args.checkpoint_dir:
        mgr = CheckpointManager(args.checkpoint_dir)
        state = mgr.restore(state)
        print(f"[serve] restored step {int(state['step'])}")

    engine = Engine(cfg, state["params"], state["adapt"])
    key = jax.random.PRNGKey(0)
    prompts = jax.random.randint(key, (args.batch, args.tokens), 0,
                                 cfg.model.vocab_size)
    t0 = time.perf_counter()
    out, _ = engine.generate(prompts, args.max_new,
                             temperature=args.temperature)
    dt = time.perf_counter() - t0
    toks = args.batch * args.max_new
    print(f"[serve] generated {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s incl. compile)")
    print("[serve] sample:", [int(t) for t in out[0][:16]])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
