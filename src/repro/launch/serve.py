"""Serving launcher: load a checkpoint (or fresh init), quantize once at the
AdaPT controller's final ⟨WL,FL⟩, and serve batched generation requests.

Batch mode (default) drives the simple ``Engine``:

    PYTHONPATH=src python -m repro.launch.serve --arch tiny --tokens 16 \
        --batch 4 --max-new 8

Continuous mode (``--continuous``) drives the overload-robust
``ContinuousBatcher`` — admission control, deadlines, a durable request
journal, and AdaBits-style precision degradation under queue pressure
(docs/serving.md):

    PYTHONPATH=src python -m repro.launch.serve --arch tiny --continuous \
        --requests 16 --max-new 8 --journal /tmp/serve.journal \
        --override serve.max_queue=8 serve.degrade_high_watermark=4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.config import load_config
from repro.serve.engine import Engine
from repro.train import train_loop
from repro.train.checkpoint import CheckpointManager


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batcher with admission control, "
                         "journal, and precision degradation")
    ap.add_argument("--requests", type=int, default=16,
                    help="[continuous] synthetic requests to submit")
    ap.add_argument("--timeout", type=float, default=0.0,
                    help="[continuous] per-request deadline in seconds")
    ap.add_argument("--journal", default="",
                    help="[continuous] durable request journal path")
    ap.add_argument("--no-degrade", action="store_true",
                    help="[continuous] disable the precision policy")
    ap.add_argument("--override", action="append", default=[])
    args = ap.parse_args(argv)

    if args.smoke:
        from repro.configs import get_smoke_config
        from repro.config import apply_overrides
        cfg = apply_overrides(get_smoke_config(args.arch), args.override)
    else:
        cfg = load_config(args.arch, overrides=args.override)

    state = train_loop.init_state(cfg)
    if args.checkpoint_dir:
        mgr = CheckpointManager(args.checkpoint_dir)
        state = mgr.restore(state)
        print(f"[serve] restored step {int(state['step'])}")

    if args.continuous:
        return _serve_continuous(cfg, state, args)

    engine = Engine(cfg, state["params"], state["adapt"])
    key = jax.random.PRNGKey(0)
    prompts = jax.random.randint(key, (args.batch, args.tokens), 0,
                                 cfg.model.vocab_size)
    t0 = time.perf_counter()
    out, _ = engine.generate(prompts, args.max_new,
                             temperature=args.temperature)
    dt = time.perf_counter() - t0
    toks = args.batch * args.max_new
    print(f"[serve] generated {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s incl. compile)")
    print("[serve] sample:", [int(t) for t in out[0][:16]])
    return 0


def _serve_continuous(cfg, state, args):
    from repro.serve.policy import PrecisionPolicy
    from repro.serve.scheduler import ContinuousBatcher, DrainTimeout

    policy = (None if args.no_degrade
              else PrecisionPolicy.from_config(cfg.serve))
    cb = ContinuousBatcher(cfg, state["params"], state["adapt"],
                           policy=policy, journal_path=args.journal)
    key = jax.random.PRNGKey(1)
    plen = min(args.tokens, cb.max_context - 1)
    for r in range(args.requests):
        prompt = [int(t) for t in jax.random.randint(
            jax.random.fold_in(key, r), (plen,), 0, cfg.model.vocab_size)]
        cb.submit(prompt, max_new_tokens=args.max_new,
                  temperature=args.temperature,
                  timeout=args.timeout or None)
    t0 = time.perf_counter()
    try:
        done = cb.run_until_drained()
    except DrainTimeout as e:
        print(f"[serve] DRAIN TIMEOUT: stranded rids {sorted(e.unfinished)}")
        done = e.done
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done)
    print(f"[serve] {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / max(dt, 1e-9):.1f} tok/s incl. compile)")
    print(f"[serve] stats: {dict(cb.stats)}")
    if policy is not None and cb.wl_trace:
        print(f"[serve] WL trace: start={cb.wl_trace[0]} "
              f"min={min(cb.wl_trace)} end={cb.wl_trace[-1]} "
              f"switches={cb.stats.get('precision_switches', 0)}")
    by_status = {}
    for r in done:
        by_status.setdefault(r.status.value, []).append(r.rid)
    for status, rids in sorted(by_status.items()):
        print(f"[serve]   {status}: {len(rids)}")
    if cb.journal is not None:
        cb.journal.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
