"""Deterministic synthetic data pipelines.

Every batch is a pure function of (seed, step): a restart at step k
reproduces the exact stream the crashed run would have seen (stateless
resumability — DESIGN.md §5.6). No files, no external downloads (the
container is offline; real CIFAR/web corpora are unavailable, documented in
EXPERIMENTS.md).

LM stream: per-sequence "stride induction" — tokens follow
t_i = (start + i·stride) mod V with 5% uniform corruption. The next token is
predictable from any two previous clean tokens, so models show real learning
curves (loss drops toward the corruption floor) without any corpus.

CIFAR stream: fixed per-class prototype images + Gaussian noise, linearly
separable but noisy enough that accuracy trajectories mirror real training
dynamics qualitatively.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.config import Config

Array = jax.Array


def _step_key(seed: int, step: int, salt: int = 0) -> Array:
    return jax.random.fold_in(jax.random.fold_in(
        jax.random.PRNGKey(seed), step), salt)


def lm_tokens(key: Array, batch: int, seq: int, vocab: int,
              noise: float = 0.05) -> Array:
    ks = jax.random.split(key, 4)
    start = jax.random.randint(ks[0], (batch, 1), 0, vocab)
    stride = jax.random.randint(ks[1], (batch, 1), 1, max(vocab // 4, 2))
    idx = jnp.arange(seq, dtype=jnp.int32)[None, :]
    toks = (start + idx * stride) % vocab
    corrupt = jax.random.bernoulli(ks[2], noise, (batch, seq))
    rand = jax.random.randint(ks[3], (batch, seq), 0, vocab)
    return jnp.where(corrupt, rand, toks).astype(jnp.int32)


def lm_batch(cfg: Config, step: int) -> Dict[str, Array]:
    """Batch dict for the unified transformer: tokens / embeds / memory."""
    m, t = cfg.model, cfg.train
    key = _step_key(t.seed, step)
    if m.is_encoder:
        ks = jax.random.split(key, 2)
        # stub frontend output + framewise labels correlated with the input
        emb = jax.random.normal(ks[0], (t.global_batch, t.seq_len, m.d_model),
                                jnp.float32)
        labels = (jnp.argmax(emb[..., :m.vocab_size], axis=-1)).astype(jnp.int32)
        return {"embeds": emb, "labels": labels}
    batch = {"tokens": lm_tokens(key, t.global_batch, t.seq_len, m.vocab_size)}
    if m.cross_attn_every:
        batch["memory"] = jax.random.normal(
            jax.random.fold_in(key, 1),
            (t.global_batch, m.num_image_tokens, m.d_model), jnp.float32)
    return batch


_PROTO_CACHE = {}


def cifar_prototypes(num_classes: int, seed: int = 7) -> Array:
    ck = (num_classes, seed)
    if ck not in _PROTO_CACHE:
        _PROTO_CACHE[ck] = jax.random.normal(
            jax.random.PRNGKey(seed), (num_classes, 32, 32, 3), jnp.float32)
    return _PROTO_CACHE[ck]


def cifar_batch(num_classes: int, batch: int, step: int, seed: int = 0,
                sigma: float = 1.5) -> Dict[str, Array]:
    key = _step_key(seed, step, salt=1)
    ks = jax.random.split(key, 2)
    labels = jax.random.randint(ks[0], (batch,), 0, num_classes)
    protos = cifar_prototypes(num_classes)
    images = protos[labels] + sigma * jax.random.normal(
        ks[1], (batch, 32, 32, 3), jnp.float32)
    return {"images": images, "labels": labels}
