"""§Perf hillclimb driver: compile one cell under a set of overrides and
print the three roofline terms + collective breakdown, appending the record
to experiments/perf/<tag>.json for the EXPERIMENTS.md log.

    PYTHONPATH=src:. python tools/hillclimb.py --arch granite-8b \
        --shape train_4k --tag bf16-container \
        --override quant.container_dtype=bfloat16
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse  # noqa: E402
import json      # noqa: E402

from repro.config import load_config                 # noqa: E402
from repro.launch.dryrun import lower_cell           # noqa: E402
from repro.roofline import analysis                  # noqa: E402


def run(arch, shape, overrides, tag, multi_pod=False, out="experiments/perf"):
    rec = lower_cell(arch, shape, multi_pod=multi_pod, do_compile=True,
                     overrides=overrides)
    rec["tag"] = tag
    rec["overrides"] = overrides
    if rec["status"] != "compiled":
        print(f"[{tag}] {rec['status']}: {rec.get('error', rec.get('reason'))}")
        return rec
    t = analysis.roofline_terms(rec)
    chips = 512 if multi_pod else 256
    useful = ""
    if rec.get("kind") == "train":
        cfg = load_config(arch, shape, overrides=overrides)
        useful = f" useful={analysis.usefulness(rec, cfg, chips):.3f}"
    print(f"[{tag}] {arch}×{shape}  compute={t['compute_s'] * 1e3:8.1f}ms  "
          f"memory={t['memory_s'] * 1e3:8.1f}ms  "
          f"collective={t['collective_s'] * 1e3:8.1f}ms  "
          f"-> {t['bottleneck'].replace('_s', '')}{useful}")
    coll = rec.get("collectives", {})
    print(f"        collectives: " + "  ".join(
        f"{k}={v / 2**30:.1f}GiB" for k, v in sorted(coll.items()) if v))
    rec["terms"] = {k: v for k, v in t.items() if isinstance(v, float)}
    os.makedirs(out, exist_ok=True)
    name = f"{arch}_{shape}_{tag}".replace("/", "_").replace(".", "_")
    with open(os.path.join(out, name + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--tag", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--override", action="append", default=[])
    a = ap.parse_args()
    run(a.arch, a.shape, a.override, a.tag, a.multi_pod)
