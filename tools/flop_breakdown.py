"""Debug tool: per-op-name FLOP attribution for one dry-run cell."""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import re
from collections import defaultdict

import jax

from repro import sharding
from repro.config import load_config, shape_kind
from repro.launch import mesh as mesh_lib, specs as specs_lib
from repro.roofline import hlo_costs
from repro.serve import engine as engine_lib
from repro.train import train_loop


def compile_cell(arch, shape, multi_pod=False, overrides=None):
    cfg = load_config(arch, shape, overrides=overrides)
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    kind = shape_kind(shape)
    rkind = "long" if shape == "long_500k" else kind
    rules = mesh_lib.make_rules(cfg, mesh, rkind)
    with sharding.use_rules(mesh, rules):
        if kind == "train":
            st = specs_lib.state_specs(cfg)
            bt = specs_lib.batch_specs(cfg)
            jfn = jax.jit(train_loop.make_train_step(cfg),
                          in_shardings=(mesh_lib.state_shardings(st, cfg, mesh),
                                        mesh_lib.batch_shardings(bt, mesh)),
                          out_shardings=(mesh_lib.state_shardings(st, cfg, mesh), None))
            return jfn.lower(st, bt).compile(), cfg
        elif kind == "prefill":
            sp = specs_lib.prefill_specs(cfg)
            qsh = mesh_lib.param_shardings(sp["qparams"], cfg, mesh)
            dsh = mesh_lib.batch_shardings(
                {k: v for k, v in sp.items() if k != "qparams"}, mesh)
            pf = engine_lib.make_prefill(cfg)
            args = [sp["qparams"], sp["tokens"]]
            in_sh = [qsh, dsh["tokens"]]
            if "memory" in sp:
                args.append(sp["memory"]); in_sh.append(dsh["memory"])
            return jax.jit(pf, in_shardings=tuple(in_sh)).lower(*args).compile(), cfg
        else:
            sp = specs_lib.decode_specs(cfg)
            qsh = mesh_lib.param_shardings(sp["qparams"], cfg, mesh)
            csh = mesh_lib.cache_shardings(sp["caches"], cfg, mesh, rkind)
            tsh = (mesh_lib.batch_shardings({"token": sp["token"]}, mesh)["token"]
                   if shape != "long_500k" else mesh_lib.replicated(mesh))
            fn = engine_lib.make_decode(cfg)
            jfn = jax.jit(fn, in_shardings=(qsh, tsh, csh, mesh_lib.replicated(mesh)),
                          out_shardings=(None, csh))
            return jfn.lower(sp["qparams"], sp["token"], sp["caches"], sp["t"]).compile(), cfg


def breakdown(text, top=20):
    comps = hlo_costs.parse_module(text)
    mult = defaultdict(float)
    entry = next(c.name for c in comps.values() if c.is_entry)
    mult[entry] = 1.0
    order, seen, i = [entry], {entry}, 0
    while i < len(order):
        name = order[i]; i += 1
        comp = comps[name]
        for op in comp.ops:
            if op.kind == "while":
                m = hlo_costs._COND_BODY_RE.search(op.line)
                if m:
                    cond, body = m.groups()
                    t, _ = hlo_costs._trip_count(
                        comps.get(cond, hlo_costs.Computation(cond)))
                    for ch in (body, cond):
                        mult[ch] += mult[name] * t
                        if ch not in seen:
                            seen.add(ch); order.append(ch)
            else:
                m = hlo_costs._CALLS_RE.search(op.line)
                if m:
                    ch = m.group(1)
                    mult[ch] += mult[name]
                    if ch not in seen:
                        seen.add(ch); order.append(ch)
    agg = defaultdict(float)
    coll = defaultdict(float)
    for name, comp in comps.items():
        for op in comp.ops:
            mm = re.search(r'op_name="([^"]+)"', op.line)
            tag = mm.group(1) if mm else op.name
            tag = re.sub(r"\d+", "#", tag)[-120:]
            if op.kind in ("dot", "convolution"):
                f = (hlo_costs._dot_flops(op, comp) if op.kind == "dot"
                     else hlo_costs._conv_flops(op, comp))
                agg[tag] += f * mult.get(name, 1.0)
            base = op.kind.replace("-start", "")
            if base in hlo_costs.COLLECTIVES:
                coll[f"{base} :: {tag}"] += (hlo_costs._op_bytes(op)
                                             * mult.get(name, 1.0))
    total = sum(agg.values())
    print(f"total dot flops/chip: {total:.4e}")
    for tag, f in sorted(agg.items(), key=lambda kv: -kv[1])[:top]:
        print(f"{f:.3e} {f / total * 100:5.1f}%  {tag}")
    ctotal = sum(coll.values())
    print(f"\ntotal collective bytes/chip: {ctotal / 2**30:.1f} GiB")
    for tag, b in sorted(coll.items(), key=lambda kv: -kv[1])[:top]:
        print(f"{b / 2**30:8.2f} GiB {b / max(ctotal, 1) * 100:5.1f}%  {tag}")
    return total


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--override", action="append", default=[])
    args = ap.parse_args()
    compiled, cfg = compile_cell(args.arch, args.shape, args.multi_pod,
                                 args.override)
    breakdown(compiled.as_text())
