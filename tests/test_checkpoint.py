"""Checkpoint robustness: full AdaPT-state restore (int32 ⟨WL,FL⟩ leaves,
packed containers), CRC/torn-write paths, async-save error surfacing, and
the SIGTERM→final-checkpoint preemption contract with resume parity."""
import dataclasses
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import load_config
from repro.train import train_loop
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import Heartbeat, PreemptionGuard


def _cfg(container="float32", **train_kw):
    cfg = load_config("tiny")
    return dataclasses.replace(
        cfg,
        train=dataclasses.replace(cfg.train, adapt_interval=2, **train_kw),
        quant=dataclasses.replace(cfg.quant, container_dtype=container))


def _adapt_leaves(state):
    return {path: ts for path, ts in state["adapt"]["tensors"].items()}


# ---------------------------------------------------------------------------
# Full AdaPT state restore


@pytest.mark.parametrize("container", ["float32", "int8_packed"])
def test_restore_preserves_adapt_state_exactly(container, tmp_path):
    """The controller's int32 ⟨WL,FL⟩ / lookback / resolution leaves must
    survive the npz round trip bit-exactly (they drive requantization —
    a float detour would silently corrupt precision choices), for both
    the simulate-grid and the packed-int8 container configs."""
    cfg = _cfg(container)
    state, _ = train_loop.train(cfg, steps=6, log=lambda s: None)
    assert state["adapt"]["tensors"], "controller state empty — bad setup"
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(state, step=6)
    restored = mgr.restore(train_loop.init_state(cfg))

    for path, ts in _adapt_leaves(state).items():
        rts = restored["adapt"]["tensors"][path]
        for field in ("wl", "fl", "lb", "res"):
            assert rts[field].dtype == jnp.int32, (path, field)
            np.testing.assert_array_equal(np.asarray(ts[field]),
                                          np.asarray(rts[field]),
                                          err_msg=f"{path}.{field}")
        for field in ("count", "norm_sum", "grad_sum"):
            np.testing.assert_allclose(np.asarray(ts[field], np.float32),
                                       np.asarray(rts[field], np.float32),
                                       err_msg=f"{path}.{field}")
    # resumed training must run (precision switches included) from the
    # restored controller state without error and advance the step counter
    st2, _ = train_loop.train(cfg, steps=4, state=restored,
                              log=lambda s: None)
    assert int(st2["step"]) == 10


def test_restore_missing_done_falls_back(tmp_path):
    cfg = _cfg()
    state, _ = train_loop.train(cfg, steps=2, log=lambda s: None)
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(state, step=2)
    st4, _ = train_loop.train(cfg, steps=2, state=state, log=lambda s: None)
    mgr.save(st4, step=4)
    os.remove(tmp_path / "step_00000004" / "DONE")   # simulated torn write
    assert mgr.latest_step() == 2
    restored = mgr.restore(train_loop.init_state(cfg))
    assert int(restored["step"]) == 2


def test_restore_crc_mismatch_raises(tmp_path):
    cfg = _cfg()
    state, _ = train_loop.train(cfg, steps=2, log=lambda s: None)
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(state, step=2)
    npz = tmp_path / "step_00000002" / "arrays.npz"
    data = bytearray(npz.read_bytes())
    data[len(data) // 2] ^= 0xFF                     # flip a payload bit
    npz.write_bytes(bytes(data))
    with pytest.raises(IOError, match="CRC"):
        mgr.restore(train_loop.init_state(cfg))


# ---------------------------------------------------------------------------
# Async-save error surfacing


def test_async_save_failure_surfaces_on_wait(tmp_path):
    """A failing writer thread must not die silently: the error is
    re-raised on the next wait()/save(), and the manager recovers for
    subsequent saves once the cause is fixed."""
    cfg = _cfg()
    state, _ = train_loop.train(cfg, steps=2, log=lambda s: None)
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    # point the writer at a path whose parent is a regular FILE — makedirs
    # raises on any platform, even running as root (chmod won't stop root)
    blocker = tmp_path / "blocker"
    blocker.write_text("not a directory")
    mgr.dir = str(blocker / "nested")
    mgr.save(state, step=2)
    with pytest.raises(IOError, match="async checkpoint save failed"):
        mgr.wait()
    # the error is consumed: the manager works again at a good path
    mgr.dir = str(tmp_path)
    mgr.save(state, step=2)
    mgr.wait()
    assert mgr.latest_step() == 2


def test_async_save_failure_surfaces_on_next_save(tmp_path):
    cfg = _cfg()
    state, _ = train_loop.train(cfg, steps=2, log=lambda s: None)
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    blocker = tmp_path / "blocker"
    blocker.write_text("x")
    mgr.dir = str(blocker / "nested")
    mgr.save(state, step=2)
    mgr._thread.join()          # let the failure land without consuming it
    mgr.dir = str(tmp_path)
    with pytest.raises(IOError, match="async checkpoint save failed"):
        mgr.save(state, step=3)


# ---------------------------------------------------------------------------
# Preemption contract


def test_sigterm_saves_final_checkpoint_and_resume_matches(tmp_path):
    """SIGTERM mid-loop → the loop saves a final checkpoint at the
    interrupted step and returns early (the wired-in contract). Training
    resumed from that checkpoint must match an uninterrupted run exactly
    (batches and SR noise key off the step index, so the trajectory is
    deterministic)."""
    cfg = _cfg(checkpoint_every=100)    # periodic saves out of the way

    # uninterrupted 6-step reference
    ref_state, _ = train_loop.train(cfg, steps=6, log=lambda s: None)

    mgr = CheckpointManager(str(tmp_path), async_save=False)
    fired = []

    def emit(line):
        if "step=3 " in line and not fired:
            fired.append(True)
            os.kill(os.getpid(), signal.SIGTERM)

    with PreemptionGuard() as guard:
        st, _ = train_loop.train(cfg, steps=6, checkpoint_mgr=mgr,
                                 preemption_guard=guard,
                                 heartbeat=Heartbeat(interval=0.0,
                                                     emit=emit),
                                 log=lambda s: None)
    assert fired, "heartbeat never reached step 3"
    assert int(st["step"]) == 3          # early return, not all 6 steps
    assert mgr.latest_step() == 3        # final checkpoint landed

    restored = mgr.restore(train_loop.init_state(cfg))
    resumed, _ = train_loop.train(cfg, steps=3, state=restored,
                                  log=lambda s: None)
    assert int(resumed["step"]) == 6
    for a, b in zip(jax.tree_util.tree_leaves(ref_state["params"]),
                    jax.tree_util.tree_leaves(resumed["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   err_msg="resume diverged from the "
                                           "uninterrupted trajectory")
