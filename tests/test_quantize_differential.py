"""Differential kernel-parity harness: the fused in-kernel-PRNG quantize
kernels vs the pure-jnp oracles in ``kernels/ref.py``, word for word.

Under interpret mode (CPU CI — this suite) the kernels draw the portable
counter-hash stream, which ref.py regenerates exactly
(``ref_fused_noise``): parity here is BIT-EXACT, not statistical. The
sweep covers the full WL∈{2..16} × FL grid, per-layer-stacked shapes with
heterogeneous ⟨WL,FL⟩ (L∈{1,4,12}), odd / non-tile-aligned trailing dims,
pathological values (±0, denormals, inf-adjacent magnitudes, all-equal
tensors), the int8-word flavor, the degenerate (size-1-mesh) shard_map
wrapper, and the controller wiring on top — ~250 parameterized cases.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import QuantConfig
from repro.core import controller
from repro.kernels import ops, ref
from repro.kernels import sr_quantize as sq

KEY = jax.random.PRNGKey(7)

WLS = list(range(2, 17))                 # the full WL ladder
FLS = [-4, -1, 0, 1, 2, 4, 8, 12]
INT8_FLS = [-3, -1, 0, 2, 4, 5, 6, 7]


def _eq(got, want, msg=""):
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                  err_msg=msg)


# ---------------------------------------------------------------------------
# Full WL × FL grid, bit-exact (120 cases; one compile — ⟨WL,FL⟩ is traced)


@pytest.mark.parametrize("fl", FLS)
@pytest.mark.parametrize("wl", WLS)
def test_grid_bit_parity(wl, fl):
    x = jax.random.normal(jax.random.fold_in(KEY, wl * 31 + fl), (613,)) * 2.5
    seed = wl * 131 + fl
    _eq(ops.sr_quantize_fused(x, seed, wl, fl, use_pallas=True),
        ref.ref_sr_quantize_fused_words(x, seed, wl, fl))


@pytest.mark.parametrize("fl", INT8_FLS)
def test_grid_bit_parity_int8(fl):
    x = jax.random.normal(jax.random.fold_in(KEY, fl + 8), (517,)) * 3
    _eq(ops.sr_quantize_fused_int8(x, fl + 99, fl, use_pallas=True),
        ref.ref_sr_quantize_fused_int8_words(x, fl + 99, fl))


# ---------------------------------------------------------------------------
# Per-layer-stacked heterogeneous ⟨WL,FL⟩ (the PR-2 tentpole regime)


@pytest.mark.parametrize("draw", [0, 1])
@pytest.mark.parametrize("trail", [(7,), (33, 65), (128, 512)])
@pytest.mark.parametrize("L", [1, 4, 12])
def test_stacked_heterogeneous_bit_parity(L, trail, draw):
    rng = np.random.RandomState(L * 100 + len(trail) * 10 + draw)
    wl = jnp.asarray(rng.randint(2, 17, L), jnp.int32)
    fl = jnp.asarray(rng.randint(-2, 13, L), jnp.int32)
    x = jax.random.normal(jax.random.fold_in(KEY, L + draw), (L,) + trail) * 2
    _eq(ops.sr_quantize_fused(x, 5 + draw, wl, fl, use_pallas=True),
        ref.ref_sr_quantize_fused_stacked_words(x, 5 + draw, wl, fl),
        f"L={L} wl={wl} fl={fl}")


@pytest.mark.parametrize("L", [1, 4, 12])
def test_stacked_heterogeneous_bit_parity_int8(L):
    rng = np.random.RandomState(L)
    fl = jnp.asarray(rng.randint(-2, 8, L), jnp.int32)
    x = jax.random.normal(jax.random.fold_in(KEY, L), (L, 37, 33)) * 4
    _eq(ops.sr_quantize_fused_int8(x, L * 7, fl, use_pallas=True),
        ref.ref_sr_quantize_fused_stacked_int8_words(x, L * 7, fl))


@pytest.mark.parametrize("wl", WLS)
def test_stacked_l1_is_unstacked(wl):
    """The stacked kernel's stream indexes the padded stack flat, so L=1
    must be bit-identical to the unstacked kernel at the same ⟨WL,FL⟩."""
    x = jax.random.normal(jax.random.fold_in(KEY, wl), (1, 47, 130))
    wlv = jnp.asarray([wl], jnp.int32)
    flv = jnp.asarray([wl // 2], jnp.int32)
    _eq(ops.sr_quantize_fused(x, 3, wlv, flv, use_pallas=True)[0],
        ops.sr_quantize_fused(x[0], 3, wl, wl // 2, use_pallas=True))


@pytest.mark.parametrize("block_rows", [1, 3, 8, 256])
def test_stream_independent_of_block_rows(block_rows):
    """The portable stream hashes global element indices, so re-tiling the
    grid must not change a single word (stacked and unstacked)."""
    x = jax.random.normal(KEY, (2, 700, 130))
    wl = jnp.asarray([8, 5], jnp.int32)
    fl = jnp.asarray([4, 2], jnp.int32)
    base = sq.sr_quantize_fused_stacked(x, 11, wl, fl, interpret=True)
    _eq(sq.sr_quantize_fused_stacked(x, 11, wl, fl, interpret=True,
                                     block_rows=block_rows), base)
    flat = x[0]
    _eq(sq.sr_quantize_fused(flat, 11, 8, 4, interpret=True,
                             block_rows=block_rows),
        sq.sr_quantize_fused(flat, 11, 8, 4, interpret=True))


# ---------------------------------------------------------------------------
# Odd / non-tile-aligned trailing dims


ODD_SHAPES = [(1,), (127,), (511,), (512,), (513,), (640,), (2, 513),
              (129, 3), (8, 128), (3, 5, 7)]


@pytest.mark.parametrize("prec", [(8, 4), (13, 9)])
@pytest.mark.parametrize("shape", ODD_SHAPES)
def test_odd_shapes_bit_parity(shape, prec):
    wl, fl = prec
    x = jax.random.normal(jax.random.fold_in(KEY, len(shape)), shape) * 2
    _eq(ops.sr_quantize_fused(x, 23, wl, fl, use_pallas=True),
        ref.ref_sr_quantize_fused_words(x, 23, wl, fl))


@pytest.mark.parametrize("trail", [(1,), (513,), (127, 3), (5, 7, 11)])
def test_odd_shapes_stacked_bit_parity(trail):
    x = jax.random.normal(jax.random.fold_in(KEY, sum(trail)), (3,) + trail)
    wl = jnp.asarray([4, 9, 16], jnp.int32)
    fl = jnp.asarray([2, 5, 11], jnp.int32)
    _eq(ops.sr_quantize_fused(x, 29, wl, fl, use_pallas=True),
        ref.ref_sr_quantize_fused_stacked_words(x, 29, wl, fl))


# ---------------------------------------------------------------------------
# Pathological values


def _patho(name):
    return {
        "signed_zeros": jnp.array([0.0, -0.0] * 320, jnp.float32),
        "denormals": jnp.array([1e-42, -3e-41, 5e-44, -1e-45] * 160,
                               jnp.float32),
        "inf_adjacent": jnp.array([3.3e38, -3.3e38, 1e30, -1e25] * 160,
                                  jnp.float32),
        "all_equal": jnp.full((640,), 0.3, jnp.float32),
        "all_equal_negative": jnp.full((640,), -1.75, jnp.float32),
        "mixed_extremes": jnp.array([0.0, -0.0, 1e-42, 3.3e38, -3.3e38,
                                     0.5, -0.5, 1.0] * 80, jnp.float32),
    }[name]


PATHO = ["signed_zeros", "denormals", "inf_adjacent", "all_equal",
         "all_equal_negative", "mixed_extremes"]


@pytest.mark.parametrize("prec", [(2, 0), (8, 4), (16, 12)])
@pytest.mark.parametrize("case", PATHO)
def test_pathological_bit_parity(case, prec):
    wl, fl = prec
    x = _patho(case)
    _eq(ops.sr_quantize_fused(x, 31, wl, fl, use_pallas=True),
        ref.ref_sr_quantize_fused_words(x, 31, wl, fl), case)


@pytest.mark.parametrize("case", PATHO)
def test_pathological_stacked_bit_parity(case):
    x = jnp.stack([_patho(case), -_patho(case)])
    wl = jnp.asarray([3, 14], jnp.int32)
    fl = jnp.asarray([1, 10], jnp.int32)
    _eq(ops.sr_quantize_fused(x, 37, wl, fl, use_pallas=True),
        ref.ref_sr_quantize_fused_stacked_words(x, 37, wl, fl), case)


@pytest.mark.parametrize("case", PATHO)
def test_pathological_bit_parity_int8(case):
    x = _patho(case)
    _eq(ops.sr_quantize_fused_int8(x, 41, 4, use_pallas=True),
        ref.ref_sr_quantize_fused_int8_words(x, 41, 4), case)


# ---------------------------------------------------------------------------
# Container dtypes


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("stacked", [False, True])
def test_dtype_containers_bit_parity(dtype, stacked):
    if stacked:
        x = (jax.random.normal(KEY, (2, 65, 33)) * 2).astype(dtype)
        wl = jnp.asarray([6, 11], jnp.int32)
        fl = jnp.asarray([3, 7], jnp.int32)
        _eq(ops.sr_quantize_fused(x, 43, wl, fl, use_pallas=True),
            ref.ref_sr_quantize_fused_stacked_words(x, 43, wl, fl))
    else:
        x = (jax.random.normal(KEY, (650,)) * 2).astype(dtype)
        _eq(ops.sr_quantize_fused(x, 43, 8, 4, use_pallas=True),
            ref.ref_sr_quantize_fused_words(x, 43, 8, 4))


# ---------------------------------------------------------------------------
# Degenerate shard_map wrapper (size-1 mesh axes run on 1 device): the
# per-shard seed fold must engage and match the sharded oracle at grid
# (1,…,1). Real multi-device parity lives in tests/test_quantize_sharded.py.


@pytest.mark.parametrize("stacked", [False, True])
def test_sharded_degenerate_bit_parity(stacked):
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    if stacked:
        x = jax.random.normal(KEY, (4, 16, 64))
        sh = NamedSharding(mesh, P("data", None, "model"))
        wl = jnp.asarray([4, 8, 12, 16], jnp.int32)
        fl = jnp.asarray([2, 4, 8, 10], jnp.int32)
        _eq(ops.sr_quantize_fused(x, 47, wl, fl, use_pallas=True,
                                  sharding=sh),
            ref.ref_sr_quantize_fused_sharded_words(x, 47, wl, fl,
                                                    (1, 1, 1)))
    else:
        x = jax.random.normal(KEY, (8, 64))
        sh = NamedSharding(mesh, P("data", "model"))
        _eq(ops.sr_quantize_fused(x, 47, 8, 4, use_pallas=True, sharding=sh),
            ref.ref_sr_quantize_fused_sharded_words(x, 47, 8, 4, (1, 1)))


# ---------------------------------------------------------------------------
# Grid exactness across dispatch regimes: XLA CPU's exp2 is off an ulp at
# |FL| ≳ 10 (exp2(15) = 32767.984), which used to put the XLA-path grid off
# its exact powers of two at high FL while the kernels were exact. Both
# must sit on the same exact grid now, whatever regime a leaf lands in.


@pytest.mark.parametrize("prec", [(16, 12), (16, 15), (12, 10), (8, -12)])
def test_xla_and_kernel_grids_are_exact(prec):
    from repro.core import fixed_point as fxp
    wl, fl = prec
    scale = float(fxp.pow2i(fl))
    assert scale == 2.0 ** fl
    x = jax.random.normal(jax.random.fold_in(KEY, wl), (640,)) * 4
    u = ref.ref_fused_noise(3, x.size).reshape(x.shape)
    q_xla = fxp.quantize(x, wl, fl, u=u)
    # every XLA-path word is an integer on the 2^-FL grid, in range
    words = np.asarray(q_xla) * 2.0 ** fl
    np.testing.assert_array_equal(words, np.round(words))
    assert words.max() <= 2.0 ** (wl - 1) - 1 and \
        words.min() >= -(2.0 ** (wl - 1))
    # and identical to the kernel-side semantics for the same noise bits
    _eq(q_xla, ref.ref_sr_quantize(x, u, wl, fl))


def test_int8_dequant_scale_exact_in_bf16():
    """The packed/int8 dequant scale 2^-FL must be an EXACT power of two in
    bf16 — bf16 exp2 is off by up to ~3% (exp2(-10) → 0.00099945), which
    would dequantize every int8 word onto a wrong, off-grid value."""
    from repro.core import fixed_point as fxp
    for fl in range(-8, 17):
        sc = float(fxp.pow2i(jnp.int32(-fl)).astype(jnp.bfloat16))
        assert sc == 2.0 ** -fl, fl


def test_fallback_refuses_sharding():
    """use_pallas=False cannot honor the per-shard seed contract or the
    no-collective guarantee — it must refuse, not silently degrade."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
    sh = NamedSharding(mesh, P("data"))
    x = jnp.ones((8,))
    with pytest.raises(ValueError, match="use_pallas"):
        ops.sr_quantize_fused(x, 0, 8, 4, use_pallas=False, sharding=sh)
    with pytest.raises(ValueError, match="use_pallas"):
        ops.sr_quantize_fused_int8(x, 0, 4, use_pallas=False, sharding=sh)


# ---------------------------------------------------------------------------
# Controller wiring on top of the kernels: quantize_params{,_packed} must
# hand every regime the right seed/precision and come back word-identical.


@pytest.mark.parametrize("container", ["float32", "int8"])
def test_quantize_params_matches_oracles(container):
    qcfg = dataclasses.replace(QuantConfig(), use_pallas=True)
    params = {"dense": {"w": jax.random.normal(KEY, (48, 64))},
              "blocks": {"mlp": {"w": jax.random.normal(KEY, (3, 24, 40))}}}
    st = controller.init_adapt_state(params, qcfg)
    # heterogeneous per-layer precision, as after a precision switch
    ts = st["tensors"]["blocks/mlp/w"]
    ts["wl"] = jnp.asarray([4, 8, 13], jnp.int32)
    ts["fl"] = jnp.asarray([2, 4, 9], jnp.int32)
    dtype = jnp.int8 if container == "int8" else jnp.float32
    q = controller.quantize_params(params, st, qcfg, key=KEY, dtype=dtype)

    sd = controller._leaf_seed(KEY, "dense/w")
    sb = controller._leaf_seed(KEY, "blocks/mlp/w")
    td = st["tensors"]["dense/w"]
    if container == "int8":
        from repro.core import fixed_point as fxp
        qd = ref.ref_sr_quantize_fused_int8_words(params["dense"]["w"], sd,
                                                  td["fl"])
        want_d = (qd.astype(jnp.bfloat16)
                  * fxp.pow2i(-td["fl"]).astype(jnp.bfloat16))
        qb = ref.ref_sr_quantize_fused_stacked_int8_words(
            params["blocks"]["mlp"]["w"], sb, ts["fl"])
        want_b = (qb.astype(jnp.bfloat16)
                  * fxp.pow2i(-ts["fl"]).astype(jnp.bfloat16)
                  .reshape(3, 1, 1))
    else:
        want_d = ref.ref_sr_quantize_fused_words(params["dense"]["w"], sd,
                                                 td["wl"], td["fl"])
        want_b = ref.ref_sr_quantize_fused_stacked_words(
            params["blocks"]["mlp"]["w"], sb, ts["wl"], ts["fl"])
    _eq(q["dense"]["w"], want_d)
    _eq(q["blocks"]["mlp"]["w"], want_b)


def test_quantize_params_packed_matches_oracles():
    qcfg = dataclasses.replace(QuantConfig(), use_pallas=True)
    params = {"blocks": {"mlp": {"w": jax.random.normal(KEY, (3, 24, 40))}}}
    st = controller.init_adapt_state(params, qcfg)
    qp = controller.quantize_params_packed(params, st, qcfg, key=KEY)
    leaf = qp["blocks"]["mlp"]["w"]
    ts = st["tensors"]["blocks/mlp/w"]
    _eq(leaf["q8"],
        ref.ref_sr_quantize_fused_stacked_int8_words(
            params["blocks"]["mlp"]["w"],
            controller._leaf_seed(KEY, "blocks/mlp/w"), ts["fl"]))
    assert leaf["sc"].shape == (3, 1, 1)
