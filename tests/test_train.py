"""Training subsystem: loop integration, accumulation equivalence,
checkpoint/restore, fault tolerance, optimizer, QSGD."""
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import OptimizerConfig, load_config
from repro.quant import qsgd
from repro.train import optimizer as opt_lib
from repro.train import train_loop
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import (PreemptionGuard, StepWatchdog,
                                         StragglerEvent, retry)


def _tiny_cfg(**train_kw):
    cfg = load_config("tiny")
    return dataclasses.replace(
        cfg, train=dataclasses.replace(cfg.train, **train_kw))


def test_loss_decreases_tiny_lm():
    cfg = _tiny_cfg(adapt_interval=10, log_every=2)
    state, hist = train_loop.train(cfg, steps=24, log=lambda s: None)
    losses = [h["loss"] for h in hist]
    assert losses[-1] < losses[0]


def test_float32_mode_trains_too():
    cfg = load_config("tiny", overrides=["quant.mode=off"])
    cfg = dataclasses.replace(
        cfg, train=dataclasses.replace(cfg.train, log_every=2))
    state, hist = train_loop.train(cfg, steps=24, log=lambda s: None)
    first3 = sum(h["loss"] for h in hist[:3]) / 3
    last3 = sum(h["loss"] for h in hist[-3:]) / 3
    assert last3 < first3 + 1e-3          # trending down (12 samples, noisy)
    assert state["adapt"]["tensors"] == {}


def test_accumulation_matches_full_batch():
    """accum_steps=4 must produce (nearly) the same update as accum=1 with
    the same global batch: grads are means over the same tokens."""
    results = {}
    for accum in (1, 4):
        cfg = _tiny_cfg(accum_steps=accum, seq_len=32, global_batch=8)
        cfg = dataclasses.replace(
            cfg, quant=dataclasses.replace(cfg.quant,
                                           stochastic_rounding=False))
        state = train_loop.init_state(cfg)
        step = jax.jit(train_loop.make_train_step(cfg))
        batch = train_loop.make_batch(cfg, 0)
        new_state, metrics = step(state, batch)
        results[accum] = (new_state, metrics)
    l1, l4 = results[1][1]["loss"], results[4][1]["loss"]
    assert abs(float(l1) - float(l4)) < 5e-3
    p1 = jax.tree_util.tree_leaves(results[1][0]["params"])
    p4 = jax.tree_util.tree_leaves(results[4][0]["params"])
    err = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                    - b.astype(jnp.float32))))
              for a, b in zip(p1, p4))
    assert err < 5e-3, f"accum mismatch {err}"


def test_checkpoint_roundtrip_and_resume():
    cfg = _tiny_cfg()
    state, _ = train_loop.train(cfg, steps=3, log=lambda s: None)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, async_save=False)
        mgr.save(state, step=3)
        restored = mgr.restore(train_loop.init_state(cfg))
        for a, b in zip(jax.tree_util.tree_leaves(state),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32))
        st2, _ = train_loop.train(cfg, steps=2, state=restored,
                                  log=lambda s: None)
        assert int(st2["step"]) == 5


def test_checkpoint_gc_and_torn_write():
    cfg = _tiny_cfg()
    state = train_loop.init_state(cfg)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, async_save=False)
        for s in (1, 2, 3):
            mgr.save(state, step=s)
        assert mgr.all_steps() == [2, 3]          # GC kept last 2
        # torn write: directory without DONE must be ignored
        os.makedirs(os.path.join(d, "step_00000009"))
        assert mgr.latest_step() == 3
        # CRC failure detection
        with open(os.path.join(d, "step_00000003", "arrays.npz"), "ab") as f:
            f.write(b"corrupt")
        with pytest.raises(IOError):
            mgr.restore(train_loop.init_state(cfg), step=3)


def test_watchdog_flags_stragglers():
    events = []
    wd = StepWatchdog(factor=3.0, min_samples=3,
                      on_straggler=lambda s, dt, med: events.append(s))
    for i in range(6):
        wd.observe(i, 0.1)
    assert not events
    assert wd.observe(6, 1.0)
    assert events == [6]
    wd2 = StepWatchdog(factor=2.0, min_samples=2, max_consecutive=2)
    wd2.observe(0, 0.1)
    wd2.observe(1, 0.1)
    wd2.observe(2, 1.0)
    with pytest.raises(StragglerEvent):
        wd2.observe(3, 1.0)


def test_retry_and_preemption_guard():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise IOError("transient")
        return 42

    assert retry(flaky, attempts=4, base_delay=0.0) == 42
    with PreemptionGuard() as g:
        assert not g.requested
        import signal
        os.kill(os.getpid(), signal.SIGTERM)
        assert g.requested


def test_rop_scheduler_reduces_lr():
    ocfg = OptimizerConfig(lr=0.1, rop_patience=3, rop_factor=0.5,
                           rop_threshold=1e-3)
    st = opt_lib.init_opt_state({"w": jnp.zeros(2)}, ocfg)
    # call 1 establishes best=1.0; calls 2-4 are the 3 plateau steps
    for _ in range(4):
        st = opt_lib.rop_update(st, jnp.float32(1.0), ocfg)
    assert float(st["lr"]) == pytest.approx(0.05)
    # improvement resets patience
    st = opt_lib.rop_update(st, jnp.float32(0.5), ocfg)
    assert int(st["rop_bad"]) == 0


def test_grad_normalization_targets_quantized_only():
    grads = {"a": jnp.ones((4, 4)) * 10.0, "b": jnp.ones((4,)) * 10.0}
    out = opt_lib.normalize_grads(grads, {"a"})
    assert float(jnp.linalg.norm(out["a"])) == pytest.approx(1.0, rel=1e-5)
    assert float(jnp.max(out["b"])) == 10.0


@pytest.mark.parametrize("bits", [4, 8])
def test_qsgd_unbiased_and_bounded(bits):
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (512,)) * 2.0
    reps = 300
    decs = [qsgd.decode(*qsgd.encode(g, jax.random.fold_in(key, i), bits))
            for i in range(reps)]
    mean = jnp.mean(jnp.stack(decs), axis=0)
    step = float(jnp.max(jnp.abs(g))) / (2 ** (bits - 1) - 1)
    assert float(jnp.max(jnp.abs(mean - g))) < 4 * step / np.sqrt(reps) * 3
    # single-shot error bounded by one quantization step
    one = qsgd.decode(*qsgd.encode(g, key, bits))
    assert float(jnp.max(jnp.abs(one - g))) <= step + 1e-6


def test_adapt_interval_cadence():
    """Controller switches happen every adapt_interval steps, never inside
    the hot step."""
    cfg = _tiny_cfg(adapt_interval=5)
    telemetry = []
    state, _ = train_loop.train(cfg, steps=11, telemetry=telemetry,
                                log=lambda s: None)
    assert len(telemetry) == 2   # steps 5 and 10
