"""Golden PRNG-stream regression: the portable counter-hash noise stream
and the shard-seed folding scheme are CONTRACTS — checkpointed training
runs, the sharded quantize's cross-host reproducibility, and every
bit-exact oracle in kernels/ref.py depend on them never drifting. The
words below were generated at the stream's introduction (PR 2); any
mismatch means an (accidental or deliberate) stream change. If
deliberate, regenerate tests/golden/sr_prng_stream.json and call the
break out in CHANGES.md; if accidental, fix the kernel.
"""
import json
import os

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.kernels import sr_quantize as sq

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "sr_prng_stream.json")
DRIFT = ("PRNG STREAM DRIFT: the fused quantize kernels no longer "
         "reproduce the pinned %s — see tests/test_prng_golden.py "
         "docstring before touching the golden file.")


def _golden():
    with open(GOLDEN) as f:
        return json.load(f)


def _x():
    return jnp.sin(jnp.arange(40, dtype=jnp.float32)) * 4.0


def test_hash_stream_pinned():
    got = np.asarray(ref.ref_fused_noise(7, 32) * (1 << 24)).astype(np.uint32)
    np.testing.assert_array_equal(
        got, np.asarray(_golden()["hash_u24_seed7_first32"], np.uint32),
        err_msg=DRIFT % "counter-hash stream")


def test_fold_shard_seed_pinned():
    got = [int(sq.fold_shard_seed(jnp.int32(123), jnp.int32(i)))
           for i in range(8)]
    assert got == _golden()["fold_shard_seed123_idx0_7"], \
        DRIFT % "shard-seed folding scheme"
    # and ref.py's independent mirror must agree with the kernel-side fold
    assert got == [int(ref.ref_fold_shard_seed(123, i)) for i in range(8)]


def test_fused_quantized_words_pinned():
    got = np.asarray(
        ops.sr_quantize_fused(_x(), 42, 8, 4, use_pallas=True) * 16.0)
    np.testing.assert_array_equal(
        got, np.asarray(_golden()["fused_words_seed42_wl8_fl4"], np.float32),
        err_msg=DRIFT % "quantized word stream")


def test_stacked_quantized_words_pinned():
    x = _x()
    xs = jnp.stack([x, -x, x * 0.5])
    got = np.asarray(ops.sr_quantize_fused(
        xs, 42, jnp.asarray([5, 9, 13], jnp.int32),
        jnp.asarray([2, 5, 9], jnp.int32), use_pallas=True)
        * np.array([4.0, 32.0, 512.0], np.float32)[:, None])
    np.testing.assert_array_equal(
        got, np.asarray(_golden()["stacked_words_seed42_wl_5_9_13_fl_2_5_9"],
                        np.float32),
        err_msg=DRIFT % "stacked per-layer word stream")


def test_int8_quantized_words_pinned():
    got = np.asarray(ops.sr_quantize_fused_int8(_x(), 11, 4,
                                                use_pallas=True))
    np.testing.assert_array_equal(
        got, np.asarray(_golden()["int8_words_seed11_fl4"], np.int8),
        err_msg=DRIFT % "int8 word stream")
