"""Sharding rules / parameter specs / HLO cost walker."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import sharding
from repro.config import load_config
from repro.launch import mesh as mesh_lib
from repro.roofline import hlo_costs
from repro.roofline.analysis import roofline_terms


def test_shard_noop_without_rules():
    x = jnp.ones((4, 4))
    assert sharding.shard(x, "batch", None) is x
    assert sharding.axis_size("batch") == 1


def test_rules_resolve_specs():
    mesh = mesh_lib.make_cpu_mesh()
    with sharding.use_rules(mesh, {"batch": ("data",), "ff": ("model",)}):
        assert sharding.spec("batch", None, "ff") == P("data", None, "model")
        assert sharding.axis_size("batch") == 1   # cpu mesh is 1×1
        x = jnp.ones((4, 4))
        y = sharding.shard(x, "batch", "ff")
        assert y.shape == x.shape


def test_duplicate_mesh_axis_suppressed():
    mesh = mesh_lib.make_cpu_mesh()
    with sharding.use_rules(mesh, {"batch": ("data",), "seq": ("data",)}):
        # "data" may appear only once in a spec
        assert sharding.spec("batch", "seq") == P("data", None)


class _FakeMesh:
    """Shape-only stand-in so spec tests don't allocate 256 devices."""
    def __init__(self, shape):
        self.shape = shape


@pytest.mark.parametrize("arch", ["granite-8b", "mixtral-8x22b",
                                  "arctic-480b", "mamba2-780m"])
def test_param_pspec_rules(arch):
    cfg = load_config(arch)
    mesh = _FakeMesh({"data": 16, "model": 16})
    # column-parallel QKV / in_proj → last dim on model
    p = mesh_lib.param_pspec("blocks/s0_attn/wq", (36, 4096, 4096), cfg, mesh)
    assert p[-1] == "model"
    # row-parallel out-proj → contraction dim on model
    p = mesh_lib.param_pspec("blocks/s0_attn/wo", (36, 4096, 4096), cfg, mesh)
    assert p[-2] == "model"
    # vocab-sharded embedding
    p = mesh_lib.param_pspec("embed", (49152, 4096), cfg, mesh)
    assert p[0] == "model"
    # routers replicated
    p = mesh_lib.param_pspec("blocks/s0_moe/router", (35, 7168, 128), cfg,
                             mesh)
    assert all(x is None for x in p)


def test_param_pspec_moe_ep_vs_tp():
    mesh = _FakeMesh({"data": 16, "model": 16})
    arctic = load_config("arctic-480b")
    mixtral = load_config("mixtral-8x22b")
    # arctic: 128 experts % 16 == 0 → expert-parallel
    p = mesh_lib.param_pspec("blocks/s0_moe/we_gate", (35, 128, 7168, 4864),
                             arctic, mesh)
    assert p[1] == "model"
    # mixtral: 8 experts % 16 != 0 → TP on the ff dim instead
    p = mesh_lib.param_pspec("blocks/s0_moe/we_gate", (56, 8, 6144, 16384),
                             mixtral, mesh)
    assert p[1] is None and p[-1] == "model"
    # big tensors additionally fold the data axis (FSDP)
    assert "data" in tuple(p)


def test_param_pspec_divisibility_fallback():
    cfg = load_config("smollm-360m")
    mesh = _FakeMesh({"data": 16, "model": 16})
    # 15 heads × 64 = 960 divisible → projection still sharded
    p = mesh_lib.param_pspec("blocks/s0_attn/wq", (32, 960, 960), cfg, mesh)
    assert p[-1] == "model"
    # odd dims fall back to replication rather than failing
    p = mesh_lib.param_pspec("blocks/s0_attn/wq", (32, 7, 7), cfg, mesh)
    assert all(x is None for x in p)


def test_make_rules_head_divisibility():
    granite = load_config("granite-8b")
    smollm = load_config("smollm-360m")
    mesh = _FakeMesh({"data": 16, "model": 16})
    mesh.axis_names = ("data", "model")
    r = mesh_lib.make_rules(granite, mesh, "train")
    assert r["heads"] == ("model",)
    r = mesh_lib.make_rules(smollm, mesh, "train")
    assert r["heads"] == ()          # 15 % 16 — replicate (baseline)
    assert r["q_seq"] == ()          # off by default
    import dataclasses
    smollm2 = dataclasses.replace(
        smollm, mesh=dataclasses.replace(smollm.mesh, seq_shard_attn="auto"))
    r = mesh_lib.make_rules(smollm2, mesh, "train")
    assert r["q_seq"] == ("model",)  # hillclimb lever


def test_long_rules_shard_kv_seq():
    cfg = load_config("mamba2-780m", "long_500k")
    mesh = _FakeMesh({"data": 16, "model": 16})
    mesh.axis_names = ("data", "model")
    r = mesh_lib.make_rules(cfg, mesh, "long")
    assert r["batch"] == () and r["kv_seq"] == ("data",)


# ---------------------------------------------------------------------------
# HLO cost walker


def test_walker_counts_scan_trips():
    def scanned(x, ws):
        def b(h, w):
            return jnp.dot(h, w,
                           preferred_element_type=jnp.float32
                           ).astype(h.dtype), None
        h, _ = jax.lax.scan(b, x, ws)
        return h

    x = jax.ShapeDtypeStruct((128, 128), jnp.bfloat16)
    ws = jax.ShapeDtypeStruct((8, 128, 128), jnp.bfloat16)
    c = jax.jit(scanned).lower(x, ws).compile()
    r = hlo_costs.module_costs(c.as_text())
    assert r["flops"] == pytest.approx(8 * 2 * 128 ** 3, rel=1e-6)
    assert r["dynamic_loops"] == 0


def test_walker_nested_loops():
    def nested(x):
        def outer(h, _):
            def inner(h2, _):
                return jnp.dot(h2, h2,
                               preferred_element_type=jnp.float32
                               ).astype(h2.dtype), None
            h, _ = jax.lax.scan(inner, h, None, length=4)
            return h, None
        h, _ = jax.lax.scan(outer, x, None, length=3)
        return h

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = jax.jit(nested).lower(x).compile()
    r = hlo_costs.module_costs(c.as_text())
    assert r["flops"] == pytest.approx(12 * 2 * 64 ** 3, rel=1e-6)


def test_walker_xla_costanalysis_disagrees():
    """Documents WHY the walker exists: XLA counts loop bodies once."""
    def scanned(x, ws):
        def b(h, w):
            return jnp.dot(h, w,
                           preferred_element_type=jnp.float32
                           ).astype(h.dtype), None
        h, _ = jax.lax.scan(b, x, ws)
        return h

    x = jax.ShapeDtypeStruct((128, 128), jnp.bfloat16)
    ws = jax.ShapeDtypeStruct((8, 128, 128), jnp.bfloat16)
    c = jax.jit(scanned).lower(x, ws).compile()
    xla_flops = hlo_costs.xla_cost_analysis(c)["flops"]
    walker_flops = hlo_costs.module_costs(c.as_text())["flops"]
    # XLA reports ~1 loop body (plus small elementwise terms); the walker
    # counts all 8 trips of the matmul.
    assert walker_flops == pytest.approx(8 * 2 * 128 ** 3, rel=1e-6)
    assert xla_flops < walker_flops / 4


def test_roofline_terms_math():
    rec = {"cost": {"flops": 197e12, "bytes accessed": 819e9},
           "collectives": {"total": 50e9}}
    t = roofline_terms(rec)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["collective_s"] == pytest.approx(1.0)


def test_collective_shape_bytes():
    from repro.roofline.analysis import _shape_bytes
    assert _shape_bytes("f32[8,128]") == 8 * 128 * 4
    assert _shape_bytes("(bf16[4,4], f32[2])") == 4 * 4 * 2 + 2 * 4
