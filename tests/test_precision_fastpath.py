"""The fused precision-machinery fast path: in-kernel-PRNG quantize + the
EDF-ladder kernel, their wiring into controller/pushdown, and the structural
guarantees the perf claims rest on (no materialized noise operand, no
scatter-add histograms)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import jaxpr_tools
from repro.config import QuantConfig
from repro.core import controller, fixed_point as fxp, pushdown
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(42)


def _pathological(name):
    k = jax.random.PRNGKey(0)
    return {
        "normal": jax.random.normal(k, (9000,)),
        "zeros": jnp.zeros((5000,)),
        "spike": jnp.zeros((4096,)).at[17].set(3.7),
        "bimodal": jnp.concatenate(
            [jax.random.normal(k, (4096,)) - 4.0,
             jax.random.normal(jax.random.fold_in(k, 1), (4096,)) + 4.0]),
        "coarse": fxp.quantize(jax.random.normal(k, (8192,)), 5, 3),
    }[name]


PATHOLOGICAL = ["normal", "zeros", "spike", "bimodal", "coarse"]


# ---------------------------------------------------------------------------
# EDF-ladder kernel: histogram counts against the scatter oracle


@pytest.mark.parametrize("n", [100, 4096, 65536])
@pytest.mark.parametrize("r", [50, 100, 150])
def test_edf_ladder_counts_match_ref(n, r):
    w = jax.random.normal(jax.random.PRNGKey(n), (n,))
    fls = fxp.fl_for_wl(jnp.max(jnp.abs(w)),
                        jnp.asarray(pushdown.WL_LADDER, jnp.int32))
    got = ops.edf_ladder_hists(w, fls, r, wl_ladder=pushdown.WL_LADDER,
                               r_upr=150, use_pallas=True)
    want = ref.ref_edf_ladder_hists(w, fls, jnp.int32(r),
                                    wl_ladder=pushdown.WL_LADDER, r_upr=150)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)
    # every histogram row counts exactly n elements
    np.testing.assert_allclose(np.asarray(got).sum(axis=1), n, atol=1e-3)


@pytest.mark.parametrize("case", PATHOLOGICAL)
def test_edf_ladder_counts_pathological(case):
    w = _pathological(case)
    fls = fxp.fl_for_wl(jnp.max(jnp.abs(w)),
                        jnp.asarray(pushdown.WL_LADDER, jnp.int32))
    got = ops.edf_ladder_hists(w, fls, 100, wl_ladder=pushdown.WL_LADDER,
                               r_upr=150, use_pallas=True)
    want = ref.ref_edf_ladder_hists(w, fls, jnp.int32(100),
                                    wl_ladder=pushdown.WL_LADDER, r_upr=150)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)


# ---------------------------------------------------------------------------
# push_down: fused path picks the same ⟨WL,FL⟩ as the XLA reference


@pytest.mark.parametrize("case", PATHOLOGICAL)
@pytest.mark.parametrize("r", [50, 150])
def test_push_down_fused_parity(case, r):
    w = _pathological(case)
    want = pushdown.push_down(w, jnp.int32(r), r_upr=150, eps_kl=1e-2)
    got = pushdown.push_down(w, jnp.int32(r), r_upr=150, eps_kl=1e-2,
                             use_pallas=True)
    assert (int(got[0]), int(got[1])) == (int(want[0]), int(want[1]))


def test_push_down_fused_parity_vmapped():
    """Per-layer-stacked tensors route through a vmapped kernel launch."""
    k = jax.random.PRNGKey(5)
    ws = jnp.stack([jax.random.normal(k, (4096,)),
                    fxp.quantize(jax.random.normal(k, (4096,)), 4, 2),
                    jnp.zeros((4096,))])
    rs = jnp.array([100, 60, 150], jnp.int32)
    f = jax.vmap(lambda w, r: pushdown.push_down(
        w, r, r_upr=150, eps_kl=1e-2, use_pallas=True))
    g = jax.vmap(lambda w, r: pushdown.push_down(
        w, r, r_upr=150, eps_kl=1e-2))
    np.testing.assert_array_equal(np.asarray(f(ws, rs)), np.asarray(g(ws, rs)))


# ---------------------------------------------------------------------------
# In-kernel-PRNG stochastic rounding: grid, determinism, expectation


def test_fused_sr_on_grid_and_range():
    x = jax.random.normal(KEY, (4096,)) * 10
    q = ops.sr_quantize_fused(x, 3, 8, 4, use_pallas=True)
    scaled = np.asarray(q) * 16
    np.testing.assert_array_equal(scaled, np.round(scaled))
    assert scaled.min() >= -128 and scaled.max() <= 127


@pytest.mark.parametrize("shape", [(7,), (33, 65), (4, 3, 50), (256, 512)])
def test_fused_sr_deterministic_per_seed(shape):
    x = jax.random.normal(KEY, shape) * 3
    a = ops.sr_quantize_fused(x, 11, 8, 4, use_pallas=True)
    b = ops.sr_quantize_fused(x, 11, 8, 4, use_pallas=True)
    c = ops.sr_quantize_fused(x, 12, 8, 4, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == shape and np.asarray(a != c).any()


def test_fused_sr_expectation():
    """E[q] ≈ x on the representable range (SR is unbiased)."""
    x = jax.random.normal(KEY, (512,))
    reps = 256
    qs = jnp.stack([ops.sr_quantize_fused(x, s, 8, 4, use_pallas=True)
                    for s in range(reps)])
    clip = jnp.clip(x, -(2.0 ** 3), 2.0 ** 3 - 2.0 ** -4)
    bias = jnp.abs(jnp.mean(qs, 0) - clip)
    # SE of the mean of a ±half-step Bernoulli residual, with slack
    assert float(jnp.max(bias)) < 4 * (2.0 ** -4) / np.sqrt(reps) * 4


def test_fused_sr_int8_words():
    x = jax.random.normal(KEY, (2048,)) * 4
    q8 = ops.sr_quantize_fused_int8(x, 5, 4, use_pallas=True)
    assert q8.dtype == jnp.int8
    # dequantized words sit within one grid step of the clipped input
    deq = q8.astype(jnp.float32) / 16.0
    err = jnp.abs(deq - jnp.clip(x, -8.0, 127 / 16.0))
    assert float(jnp.max(err)) <= 1 / 16.0 + 1e-6
    # deterministic per seed
    np.testing.assert_array_equal(
        np.asarray(q8),
        np.asarray(ops.sr_quantize_fused_int8(x, 5, 4, use_pallas=True)))


def test_fused_sr_fallback_same_grid():
    """use_pallas=False oracle: same grid semantics, jax.random stream."""
    x = jax.random.normal(KEY, (1024,)) * 3
    q = ops.sr_quantize_fused(x, 9, 8, 4, use_pallas=False)
    scaled = np.asarray(q) * 16
    np.testing.assert_array_equal(scaled, np.round(scaled))


# ---------------------------------------------------------------------------
# Wiring: the hot paths actually call the kernels when use_pallas is set


def _tiny_setup(**quant_overrides):
    quant_overrides.setdefault("use_pallas", True)
    qcfg = dataclasses.replace(QuantConfig(), **quant_overrides)
    params = {"dense": {"w": jax.random.normal(KEY, (64, 64))},
              "blocks": {"mlp": {"w": jax.random.normal(KEY, (2, 32, 32))}}}
    return qcfg, params, controller.init_adapt_state(params, qcfg)


def test_quantize_params_calls_fused_kernel(monkeypatch):
    qcfg, params, st = _tiny_setup()
    calls = []
    orig = ops.sr_quantize_fused
    monkeypatch.setattr(ops, "sr_quantize_fused",
                        lambda *a, **k: calls.append(1) or orig(*a, **k))
    controller.quantize_params(params, st, qcfg, key=KEY)
    assert calls, "use_pallas set but the fused SR kernel was never called"


def test_quantize_params_packed_calls_int8_kernel(monkeypatch):
    qcfg, params, st = _tiny_setup()
    calls = []
    orig = ops.sr_quantize_fused_int8
    monkeypatch.setattr(ops, "sr_quantize_fused_int8",
                        lambda *a, **k: calls.append(1) or orig(*a, **k))
    qp = controller.quantize_params_packed(params, st, qcfg, key=KEY)
    assert calls and qp["dense"]["w"]["q8"].dtype == jnp.int8


def test_precision_switch_calls_ladder_kernel(monkeypatch):
    qcfg, params, st = _tiny_setup(lb_lwr=2, lb_upr=4)
    calls = []
    orig = ops.edf_ladder_hists
    monkeypatch.setattr(ops, "edf_ladder_hists",
                        lambda *a, **k: calls.append(1) or orig(*a, **k))
    g = jax.tree.map(lambda p: jnp.ones_like(p) * 0.01, params)
    st = controller.accumulate(st, g, jnp.float32(1.0))
    st = controller.accumulate(st, g, jnp.float32(0.9))
    controller.precision_switch(st, params, qcfg)
    assert calls, "use_pallas set but PushDown never hit the ladder kernel"


def test_precision_switch_pallas_xla_parity():
    """The fused switch must reproduce the XLA decision exactly — the
    controller tests' ⟨WL,FL⟩ grid semantics are load-bearing."""
    qcfg, params, st = _tiny_setup(lb_lwr=2, lb_upr=4)
    g = jax.tree.map(lambda p: jnp.ones_like(p) * 0.01, params)
    st = controller.accumulate(st, g, jnp.float32(1.0))
    st = controller.accumulate(st, g, jnp.float32(0.9))
    got = controller.precision_switch(st, params, qcfg)
    want = controller.precision_switch(
        st, params, dataclasses.replace(qcfg, use_pallas=False))
    for path in got["tensors"]:
        for f in ("wl", "fl", "lb", "res"):
            np.testing.assert_array_equal(
                np.asarray(got["tensors"][path][f]),
                np.asarray(want["tensors"][path][f]), err_msg=f"{path}/{f}")


def test_quantize_params_sharded_leaves_use_fused_kernel(monkeypatch):
    """Since PR 2 sharded leaves keep the 2-transfer path: the fused
    kernel is handed the leaf's NamedSharding and wraps itself in
    sharding.shard_map (per-shard folded seeds) instead of falling back
    to the XLA noise+constraint path. Multi-device parity lives in
    tests/test_quantize_sharded.py; here we pin the dispatch."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    qcfg, params, st = _tiny_setup()
    mesh = Mesh(jax.devices()[:1], ("data",))
    shardings = jax.tree.map(
        lambda leaf: NamedSharding(mesh, P("data", *([None] * (leaf.ndim - 1)))),
        params)
    calls = []
    orig = ops.sr_quantize_fused
    orig8 = ops.sr_quantize_fused_int8
    monkeypatch.setattr(
        ops, "sr_quantize_fused",
        lambda *a, **k: calls.append(k.get("sharding")) or orig(*a, **k))
    monkeypatch.setattr(
        ops, "sr_quantize_fused_int8",
        lambda *a, **k: calls.append(k.get("sharding")) or orig8(*a, **k))
    controller.quantize_params(params, st, qcfg, key=KEY,
                               shardings=shardings)
    controller.quantize_params_packed(params, st, qcfg, key=KEY,
                                      shardings=shardings)
    assert calls and all(isinstance(s, NamedSharding) for s in calls), \
        "sharded leaves no longer reach the fused kernel with their sharding"


def test_edf_ladder_rejects_int32_overflow():
    from repro.kernels import edf_ladder
    with pytest.raises(ValueError, match="overflow int32"):
        jax.eval_shape(
            lambda w, f, r: edf_ladder.edf_ladder_hists(
                w, f, r, wl_ladder=pushdown.WL_LADDER, r_upr=150),
            jax.ShapeDtypeStruct((2 ** 31,), jnp.float32),
            jax.ShapeDtypeStruct((18,), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32))


def test_quantize_params_deterministic_and_on_grid():
    qcfg, params, st = _tiny_setup()
    q1 = controller.quantize_params(params, st, qcfg, key=KEY)
    q2 = controller.quantize_params(params, st, qcfg, key=KEY)
    for a, b in zip(jax.tree.leaves(q1), jax.tree.leaves(q2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    s = np.asarray(q1["dense"]["w"]) * 16          # ⟨8,4⟩ grid
    np.testing.assert_array_equal(s, np.round(s))


# ---------------------------------------------------------------------------
# Structural guarantees (the perf claims, checkable on the jaxpr)


def test_fused_quantize_jaxpr_has_no_materialized_noise():
    """The whole point of the in-kernel PRNG: no param-sized RNG output in
    the traced program — the U[0,1) tensor must not exist. Covers scalar-
    ⟨WL,FL⟩ AND per-layer-stacked leaves (since PR 2 the stacked kernel
    serves "blocks" stacks in the same launch discipline)."""
    qcfg = dataclasses.replace(QuantConfig(), use_pallas=True)
    params = {"dense": {"w": jax.random.normal(KEY, (64, 64))},
              "head": jax.random.normal(KEY, (64, 128)),
              "blocks": {"mlp": {"w": jax.random.normal(KEY, (2, 48, 48))}}}
    st = controller.init_adapt_state(params, qcfg)
    jaxpr = jax.make_jaxpr(
        lambda p, k: controller.quantize_params(p, st, qcfg, key=k)
    )(params, KEY).jaxpr
    min_param = min(leaf.size for leaf in jax.tree.leaves(params))
    offenders = jaxpr_tools.rng_eqns_of_size(jaxpr, min_param)
    assert not offenders, [str(e) for e in offenders]


def test_baseline_quantize_jaxpr_does_materialize_noise():
    """Sanity for the test above: the XLA path DOES materialize noise, so
    the check is actually discriminating."""
    qcfg, params, st = _tiny_setup(use_pallas=False)
    jaxpr = jax.make_jaxpr(
        lambda p, k: controller.quantize_params(p, st, qcfg, key=k)
    )(params, KEY).jaxpr
    min_param = min(leaf.size for leaf in jax.tree.leaves(params))
    assert jaxpr_tools.rng_eqns_of_size(jaxpr, min_param)


def test_fused_push_down_jaxpr_scatter_free():
    w = jax.random.normal(KEY, (8192,))
    fused = jax.make_jaxpr(lambda v: pushdown.push_down(
        v, jnp.int32(100), r_upr=150, eps_kl=1e-2, use_pallas=True))(w).jaxpr
    assert jaxpr_tools.count_primitives(fused, "scatter") == 0, \
        "fused PushDown still contains scatter histograms"
    baseline = jax.make_jaxpr(lambda v: pushdown.push_down(
        v, jnp.int32(100), r_upr=150, eps_kl=1e-2))(w).jaxpr
    assert jaxpr_tools.count_primitives(baseline, "scatter") > 0


def test_fused_switch_jaxpr_scatter_free():
    qcfg, params, st = _tiny_setup(lb_lwr=2, lb_upr=4)
    g = jax.tree.map(lambda p: jnp.ones_like(p) * 0.01, params)
    st = controller.accumulate(st, g, jnp.float32(1.0))
    jaxpr = jax.make_jaxpr(
        lambda s, p: controller.precision_switch(s, p, qcfg))(st, params).jaxpr
    assert jaxpr_tools.count_primitives(jaxpr, "scatter-add") == 0
