"""§Perf lever correctness: every sharding/dtype lever must be a pure
performance choice — model outputs (up to container rounding) unchanged."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import sharding
from repro.config import load_config
from repro.launch import mesh as mesh_lib
from repro.models import transformer
from repro.train import train_loop


def _mesh3():
    return jax.make_mesh((1, 1, 1), ("pod", "data", "model"))


def _rules(cfg, mesh, kind="train"):
    return mesh_lib.make_rules(cfg, mesh, kind)


def _logits(cfg, rules_extra=None):
    m = cfg.model
    mesh = _mesh3()
    rules = _rules(cfg, mesh)
    rules.update(rules_extra or {})
    params = transformer.init_params(jax.random.PRNGKey(0), m)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              m.vocab_size)
    with sharding.use_rules(mesh, rules):
        return jax.jit(lambda p, t: transformer.forward(p, m, tokens=t))(
            params, toks)


def test_pad_heads_identical_logits():
    """Padding heads to a multiple of the TP degree must not change math."""
    from repro.configs import get_smoke_config
    base = get_smoke_config("smollm-360m")   # 3 heads in the smoke config
    ref = _logits(base, {"#pad_heads_to": None})
    padded = _logits(base, {"#pad_heads_to": 8, "heads": ()})
    assert float(jnp.max(jnp.abs(ref - padded))) < 1e-3


def test_tp_reduce_bf16_close():
    from repro.configs import get_smoke_config
    base = get_smoke_config("granite-8b")
    ref = _logits(base, {"#tp_reduce_bf16": None})
    bf16 = _logits(base, {"#tp_reduce_bf16": True})
    # bf16 dot outputs round at ~2^-8 relative
    denom = jnp.maximum(jnp.abs(ref), 1.0)
    assert float(jnp.max(jnp.abs(ref - bf16) / denom)) < 0.1


def test_split_kv_decode_consistent():
    """decode_kv_shard=seq must reproduce the default decode logits."""
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("granite-8b")
    m = cfg.model
    params = transformer.init_params(jax.random.PRNGKey(0), m)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, m.vocab_size)
    full = transformer.forward(params, m, tokens=toks)

    mesh = _mesh3()
    cfg_seq = dataclasses.replace(
        cfg, mesh=dataclasses.replace(cfg.mesh, decode_kv_shard="seq"))
    rules = mesh_lib.make_rules(cfg_seq, mesh, "decode")
    caches = transformer.init_caches(m, 2, 9, dtype=jnp.float32)
    with sharding.use_rules(mesh, rules):
        dec = jax.jit(lambda p, t, c, i: transformer.decode_step(
            p, m, t, c, i))
        for t in range(9):
            logits, caches = dec(params, toks[:, t], caches, jnp.int32(t))
    assert float(jnp.max(jnp.abs(logits - full[:, -1]))) < 0.05


def test_containers_agree_at_wl8():
    """f32 / bf16 / int8 / int8_packed containers produce identical grids
    when WL<=8 (int8 exactness boundary)."""
    losses = {}
    for container in ("float32", "bfloat16", "int8", "int8_packed"):
        cfg = load_config("tiny", overrides=[
            f"quant.container_dtype={container}", "quant.max_wl=8",
            "quant.init_wl=8", "quant.init_fl=4"])
        state = train_loop.init_state(cfg)
        batch = train_loop.make_batch(cfg, 0)
        _, metrics = jax.jit(train_loop.make_train_step(cfg))(state, batch)
        losses[container] = float(metrics["loss"])
    ref = losses["float32"]
    for k, v in losses.items():
        assert abs(v - ref) < 5e-2, (k, losses)


def test_qsgd_shard_map_single_device():
    cfg = load_config("tiny", overrides=["train.qsgd_pod_compression=true"])
    mesh = _mesh3()
    rules = mesh_lib.make_rules(cfg, mesh, "train")
    with sharding.use_rules(mesh, rules):
        step = jax.jit(train_loop.make_train_step(cfg))
        state = train_loop.init_state(cfg)
        s2, m = step(state, train_loop.make_batch(cfg, 0))
    assert bool(jnp.isfinite(m["loss"]))


def test_make_rules_modes():
    granite = load_config("granite-8b")

    class M:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    pad = dataclasses.replace(
        granite, mesh=dataclasses.replace(granite.mesh,
                                          seq_shard_attn="pad"))
    r = mesh_lib.make_rules(pad, M(), "train")
    # granite has 32 heads → divisible → no padding requested
    assert r["#pad_heads_to"] is None
    arctic = load_config("arctic-480b")
    pad2 = dataclasses.replace(
        arctic, mesh=dataclasses.replace(arctic.mesh, seq_shard_attn="pad"))
    r2 = mesh_lib.make_rules(pad2, M(), "train")
    assert r2["#pad_heads_to"] == 64        # 56 → 64
    assert r2["heads"] == ("model",)
    # split-KV decode rules
    seq = dataclasses.replace(
        granite, mesh=dataclasses.replace(granite.mesh,
                                          decode_kv_shard="seq"))
    r3 = mesh_lib.make_rules(seq, M(), "decode")
    assert r3["kv_seq"] == ("model",) and r3["heads"] == ()
