"""Continuous-batching scheduler: greedy outputs must match the static
Engine, regardless of admission order / slot reuse."""
import jax
import jax.numpy as jnp

from repro.config import load_config
from repro.serve.engine import Engine
from repro.serve.scheduler import ContinuousBatcher
from repro.train import train_loop


def _setup():
    cfg = load_config("tiny")
    state, _ = train_loop.train(cfg, steps=3, log=lambda s: None)
    return cfg, state


def test_matches_static_engine():
    cfg, state = _setup()
    prompt = [3, 5, 7, 11, 13, 17, 19, 23]
    engine = Engine(cfg, state["params"], state["adapt"])
    ref, _ = engine.generate(jnp.asarray([prompt], jnp.int32), 6)
    ref = [int(t) for t in ref[0]]

    cb = ContinuousBatcher(cfg, state["params"], state["adapt"],
                           slots=2, max_context=32)
    req = cb.submit(prompt, max_new_tokens=6)
    done = cb.run_until_drained()
    out = next(r for r in done if r.rid == req.rid).output
    assert out == ref, (out, ref)


def test_staggered_requests_complete_and_slots_recycle():
    cfg, state = _setup()
    cb = ContinuousBatcher(cfg, state["params"], state["adapt"],
                           slots=2, max_context=32)
    rids = [cb.submit([i + 1, i + 2, i + 3], max_new_tokens=3 + i).rid
            for i in range(5)]   # 5 requests > 2 slots → queueing + reuse
    done = cb.run_until_drained()
    assert sorted(r.rid for r in done) == sorted(rids)
    for r in done:
        assert len(r.output) == r.max_new_tokens
    assert cb.utilization == 0.0


def test_queue_isolation():
    """Two different prompts served concurrently must produce the same
    outputs as served alone (no cross-slot contamination)."""
    cfg, state = _setup()
    pa, pb = [2, 4, 6, 8], [30, 20, 10, 5]

    def alone(prompt):
        cb = ContinuousBatcher(cfg, state["params"], state["adapt"],
                               slots=1, max_context=32)
        cb.submit(prompt, max_new_tokens=4)
        return cb.run_until_drained()[0].output

    ra, rb = alone(pa), alone(pb)
    cb = ContinuousBatcher(cfg, state["params"], state["adapt"],
                           slots=2, max_context=32)
    ia = cb.submit(pa, max_new_tokens=4).rid
    ib = cb.submit(pb, max_new_tokens=4).rid
    done = {r.rid: r.output for r in cb.run_until_drained()}
    assert done[ia] == ra
    assert done[ib] == rb
