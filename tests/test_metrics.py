"""Metrics/observability: JSONL stream + WL summaries + loop integration."""
import tempfile

import numpy as np

from repro.config import load_config
from repro.train import train_loop
from repro.train.metrics import MetricsLogger, read_jsonl, wl_summary


def test_wl_summary_aggregates():
    snap = {
        "a": {"wl": np.array([8, 16]), "fl": np.array([4, 8]),
              "sp": np.array([1.0, 0.5]), "lb": np.array([25, 25]),
              "res": np.array([50, 50])},
        "b": {"wl": np.array(12), "fl": np.array(6), "sp": np.array(0.8),
              "lb": np.array(25), "res": np.array(50)},
    }
    s = wl_summary(snap)
    assert s["wl_min"] == 8 and s["wl_max"] == 16
    assert abs(s["wl_mean"] - 12.0) < 1e-6
    assert abs(s["size_units"] - (8 * 1.0 + 16 * 0.5 + 12 * 0.8)) < 1e-5
    assert wl_summary({}) == {}


def test_logger_roundtrip_and_training_integration():
    cfg = load_config("tiny")
    import dataclasses
    cfg = dataclasses.replace(
        cfg, train=dataclasses.replace(cfg.train, adapt_interval=4,
                                       log_every=2))
    with tempfile.TemporaryDirectory() as d:
        logger = MetricsLogger(d, run_name="t", flush_every=1)
        train_loop.train(cfg, steps=8, log=lambda s: None,
                         metrics_logger=logger)
        logger.log_event("shutdown", reason="test")
        logger.close()
        steps = read_jsonl(logger.path)
        switches = read_jsonl(logger.switch_path)
    step_recs = [r for r in steps if r["kind"] == "step"]
    assert len(step_recs) == 4                      # log_every=2, 8 steps
    assert all("loss" in r and "dt_s" in r for r in step_recs)
    assert steps[-1]["kind"] == "shutdown"
    assert len(switches) == 2                       # steps 4 and 8
    assert all(s["wl_min"] >= 2 and s["wl_max"] <= 32 for s in switches)
    assert all("tensors" in s for s in switches)
