"""Gradient-parity differential harness: jax.grad through the Pallas
custom-VJP forward kernels (fxp_matmul / int8_matmul / flash_attention) vs
XLA autodiff of the pure-jnp oracles in ``kernels/ref.py``.

Style of tests/test_quantize_differential.py: parametrized sweeps with
per-dtype pinned tolerances. Coverage: the WL/FL grid of int8 word
ranges × power-of-two scales, odd / non-tile-aligned M/K/N (single-block
clamping) AND multi-block grids (small explicit block sizes, exercising
the K/M/N accumulation loops), bf16 and f32 outputs, batched and
unbatched attention with GQA / sliding-window / softcap / non-square
Sq≠Skv, composition of both ops under jax.vjp with non-trivial
cotangents, the logsumexp residual stash, and the no-silent-fallback
jaxpr structure (forward AND backward kernel calls present when
use_pallas=True, none when False). A final section pins the end-to-end
train step: loss/grad-norm trajectories with use_pallas=True vs False
agree within tolerance.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import jaxpr_tools
from repro.config import load_config
from repro.kernels import flash_attention as fa
from repro.kernels import fxp_matmul as fm
from repro.kernels import ops, ref
from repro.train import train_loop

KEY = jax.random.PRNGKey(11)

# dtype-pinned tolerances for grad comparisons (f32 accumulation on both
# sides; differences are reduction-order only — bf16 pays its 8-bit
# mantissa on the cast of the cotangent itself)
TOL = {
    jnp.dtype(jnp.float32): dict(rtol=2e-4, atol=2e-4),
    jnp.dtype(jnp.bfloat16): dict(rtol=3e-2, atol=3e-2),
}


def _close(got, want, dtype=jnp.float32, msg=""):
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               **TOL[jnp.dtype(dtype)], err_msg=msg)


def _words(key, shape, wl):
    """int8 fixed-point words on the ⟨WL,·⟩ grid: wl ≤ 8 by storage."""
    lim = 2 ** (wl - 1)
    return jax.random.randint(key, shape, -lim, lim, jnp.int8)


# ---------------------------------------------------------------------------
# fxp_matmul: dx and dscale across the WL/FL grid, odd dims, dtypes


@pytest.mark.parametrize("wl,fl", [(2, 0), (4, 2), (5, 3), (8, 4), (8, 7),
                                   (8, -2)])
@pytest.mark.parametrize("m,k,n", [(16, 32, 16), (37, 53, 29), (100, 70, 50),
                                   (127, 257, 131)])
def test_fxp_matmul_grad_parity(m, k, n, wl, fl):
    k1, k2, k3 = jax.random.split(jax.random.fold_in(KEY, wl * 31 + fl), 3)
    x = jax.random.normal(k1, (m, k), jnp.float32)
    wq = _words(k2, (k, n), wl)
    s = jnp.ldexp(jnp.float32(1.0), -fl)
    cot = jax.random.normal(k3, (m, n), jnp.float32)

    gx_p, gs_p = jax.grad(
        lambda x, s: jnp.sum(ops.fxp_matmul(x, wq, s, use_pallas=True) * cot),
        (0, 1))(x, s)
    gx_r, gs_r = jax.grad(
        lambda x, s: jnp.sum(ref.ref_fxp_matmul(x, wq, s) * cot),
        (0, 1))(x, s)
    _close(gx_p, gx_r, msg=f"dx wl={wl} fl={fl}")
    _close(gs_p, gs_r, msg=f"dscale wl={wl} fl={fl}")
    # the closed-form oracle agrees too
    dx_o, ds_o = ref.ref_fxp_matmul_grads(x, wq, s, cot)
    _close(gx_p, dx_o)
    _close(gs_p, ds_o)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fxp_matmul_grad_dtype(dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    x = jax.random.normal(k1, (24, 48), jnp.float32).astype(dtype)
    wq = _words(k2, (48, 40), 8)
    s = jnp.float32(1 / 16)
    cot = jax.random.normal(k3, (24, 40), jnp.float32).astype(dtype)
    gp = jax.grad(lambda x: jnp.sum(
        (ops.fxp_matmul(x, wq, s, use_pallas=True) * cot)
        .astype(jnp.float32)))(x)
    gr = jax.grad(lambda x: jnp.sum(
        (ref.ref_fxp_matmul(x, wq, s) * cot).astype(jnp.float32)))(x)
    assert gp.dtype == dtype
    _close(gp, gr, dtype=dtype)


def test_fxp_matmul_grad_multiblock():
    """Small explicit blocks on aligned dims: the full 3-D grid with the
    contraction loop innermost runs in BOTH backward kernels."""
    k1, k2, k3 = jax.random.split(KEY, 3)
    x = jax.random.normal(k1, (128, 96), jnp.float32)
    wq = _words(k2, (96, 64), 8)
    s = jnp.float32(1 / 32)
    cot = jax.random.normal(k3, (128, 64), jnp.float32)
    gp = jax.grad(lambda x, s: jnp.sum(
        fm.fxp_matmul_vjp(x, wq, s, bm=32, bn=32, bk=32,
                          interpret=True) * cot), (0, 1))(x, s)
    gr = jax.grad(lambda x, s: jnp.sum(
        ref.ref_fxp_matmul(x, wq, s) * cot), (0, 1))(x, s)
    _close(gp[0], gr[0])
    _close(gp[1], gr[1])


def test_matmul_dw_kernel_matches_oracle():
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (64, 96), jnp.float32)
    dy = jax.random.normal(k2, (64, 48), jnp.float32)
    got = fm.matmul_dw(x, dy, bm=32, bn=16, bk=32, interpret=True)
    _close(got, ref.ref_matmul_dw(x, dy))


def test_matmul_dx_streams_int8_tiles():
    """The dx kernel reads the SAME int8 (K,N) weight array the forward
    does — no transposed/dequantized HBM copy appears in its jaxpr."""
    dy = jnp.ones((32, 64), jnp.float32)
    wq = jnp.ones((48, 64), jnp.int8)
    jaxpr = jax.make_jaxpr(lambda d, w: fm.matmul_dx(
        d, w, jnp.float32(0.5), interpret=True))(dy, wq).jaxpr
    (eqn,) = jaxpr_tools.pallas_eqns(jaxpr)
    in_dtypes = [v.aval.dtype for v in eqn.invars if v.aval.size >= wq.size]
    assert jnp.int8 in in_dtypes, "weights entered the dx kernel dequantized"


# ---------------------------------------------------------------------------
# int8_matmul: scale cotangents


@pytest.mark.parametrize("m,k,n", [(16, 32, 16), (48, 72, 36)])
def test_int8_matmul_scale_grad_parity(m, k, n):
    k1, k2, k3 = jax.random.split(KEY, 3)
    xq = jax.random.randint(k1, (m, k), -128, 128, jnp.int8)
    wq = jax.random.randint(k2, (k, n), -128, 128, jnp.int8)
    cot = jax.random.normal(k3, (m, n), jnp.float32)
    sx, sw = jnp.float32(0.02), jnp.float32(0.3)
    gp = jax.grad(lambda a, b: jnp.sum(
        ops.int8_matmul(xq, wq, a, b, use_pallas=True) * cot), (0, 1))(sx, sw)
    gr = jax.grad(lambda a, b: jnp.sum(
        ref.ref_int8_matmul(xq, wq, a, b) * cot), (0, 1))(sx, sw)
    _close(gp[0], gr[0], msg="dsx")
    _close(gp[1], gr[1], msg="dsw")
    do = ref.ref_int8_matmul_grads(xq, wq, sx, sw, cot)
    _close(gp[0], do[0])
    _close(gp[1], do[1])


# ---------------------------------------------------------------------------
# flash attention: dq/dk/dv across masks, GQA, dtypes, batching


ATTN_CASES = [
    dict(causal=True),
    dict(causal=False),
    dict(causal=True, window=16),
    dict(causal=True, softcap=20.0),
    dict(causal=True, window=32, softcap=10.0),
]


@pytest.mark.parametrize("kw", ATTN_CASES,
                         ids=[str(c) for c in ATTN_CASES])
@pytest.mark.parametrize("b,h,hkv", [(1, 4, 4), (2, 8, 2)])
def test_attention_grad_parity(b, h, hkv, kw):
    k1, k2, k3, k4 = jax.random.split(jax.random.fold_in(KEY, b * h), 4)
    q = jax.random.normal(k1, (b, 96, h, 32), jnp.float32)
    k = jax.random.normal(k2, (b, 96, hkv, 32), jnp.float32)
    v = jax.random.normal(k3, (b, 96, hkv, 32), jnp.float32)
    cot = jax.random.normal(k4, q.shape, jnp.float32)
    gp = jax.grad(lambda q, k, v: jnp.sum(
        ops.attention(q, k, v, use_pallas=True, bq=32, bk=32, **kw) * cot),
        (0, 1, 2))(q, k, v)
    gr = ref.ref_attention_grads(q, k, v, cot, **kw)
    for a, b_, name in zip(gp, gr, "qkv"):
        _close(a, b_, msg=f"d{name} {kw}")


@pytest.mark.parametrize("sq,skv", [(64, 128), (32, 96), (96, 96),
                                    (61, 131), (131, 257)])
def test_attention_grad_parity_prefill_offset(sq, skv):
    """Sq ≠ Skv: query positions end-aligned to the key space. The prime
    rows run partial tail-masked boundary blocks in both grid dims."""
    k1, k2, k3, k4 = jax.random.split(jax.random.fold_in(KEY, sq + skv), 4)
    q = jax.random.normal(k1, (2, sq, 4, 32), jnp.float32)
    k = jax.random.normal(k2, (2, skv, 2, 32), jnp.float32)
    v = jax.random.normal(k3, (2, skv, 2, 32), jnp.float32)
    cot = jax.random.normal(k4, q.shape, jnp.float32)
    gp = jax.grad(lambda q, k, v: jnp.sum(
        ops.attention(q, k, v, use_pallas=True, bq=32, bk=32) * cot),
        (0, 1, 2))(q, k, v)
    gr = ref.ref_attention_grads(q, k, v, cot)
    for a, b_, name in zip(gp, gr, "qkv"):
        _close(a, b_, msg=f"d{name} sq={sq} skv={skv}")


def test_attention_grad_parity_dead_rows():
    """Sq > Skv under causal end-alignment: rows with NO surviving key.
    The kernel emits exactly-0 rows (flash convention; ref_attention's
    uniform softmax over an all-masked row is meaningless) and the VJP
    must agree that those rows are constant — dv once silently dropped
    their uniform-row contribution instead."""
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    q = jax.random.normal(k1, (1, 64, 4, 16), jnp.float32)
    k = jax.random.normal(k2, (1, 32, 2, 16), jnp.float32)
    v = jax.random.normal(k3, (1, 32, 2, 16), jnp.float32)
    cot = jax.random.normal(k4, q.shape, jnp.float32)
    dead = 64 - 32                                 # q_offset = -32

    out = ops.attention(q, k, v, use_pallas=True, bq=16, bk=16)
    np.testing.assert_array_equal(np.asarray(out[:, :dead]), 0.0)

    def oracle(q, k, v):
        o = ref.ref_attention(q, k, v)
        rows = (jnp.arange(q.shape[1]) >= dead)[None, :, None, None]
        return jnp.where(rows, o, 0.0)            # ref with dead rows zeroed

    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle(q, k, v)),
                               rtol=2e-3, atol=2e-3)
    gp = jax.grad(lambda q, k, v: jnp.sum(
        ops.attention(q, k, v, use_pallas=True, bq=16, bk=16) * cot),
        (0, 1, 2))(q, k, v)
    gr = jax.grad(lambda q, k, v: jnp.sum(oracle(q, k, v) * cot),
                  (0, 1, 2))(q, k, v)
    for a, b_, name in zip(gp, gr, "qkv"):
        _close(a, b_, msg=f"d{name} with dead query rows")


def test_attention_grad_parity_odd_dims():
    """Odd / non-tile-aligned Sq, Skv and head dim (45 % 32 ≠ 0: the
    boundary blocks are partial and tail-masked)."""
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    q = jax.random.normal(k1, (1, 45, 3, 24), jnp.float32)
    k = jax.random.normal(k2, (1, 45, 3, 24), jnp.float32)
    v = jax.random.normal(k3, (1, 45, 3, 24), jnp.float32)
    cot = jax.random.normal(k4, q.shape, jnp.float32)
    gp = jax.grad(lambda q, k, v: jnp.sum(
        ops.attention(q, k, v, use_pallas=True, bq=32, bk=32) * cot),
        (0, 1, 2))(q, k, v)
    gr = ref.ref_attention_grads(q, k, v, cot)
    for a, b_, name in zip(gp, gr, "qkv"):
        _close(a, b_, msg=f"d{name}")


def test_attention_grad_parity_bf16():
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    q = jax.random.normal(k1, (1, 64, 2, 64), jnp.bfloat16)
    k = jax.random.normal(k2, (1, 64, 2, 64), jnp.bfloat16)
    v = jax.random.normal(k3, (1, 64, 2, 64), jnp.bfloat16)
    cot = jax.random.normal(k4, q.shape, jnp.bfloat16)
    gp = jax.grad(lambda q, k, v: jnp.sum(
        (ops.attention(q, k, v, use_pallas=True, bq=32, bk=32) * cot)
        .astype(jnp.float32)), (0, 1, 2))(q, k, v)
    gr = jax.grad(lambda q, k, v: jnp.sum(
        (ref.ref_attention(q, k, v) * cot).astype(jnp.float32)),
        (0, 1, 2))(q, k, v)
    for a, b_, name in zip(gp, gr, "qkv"):
        assert a.dtype == jnp.bfloat16
        _close(a, b_, dtype=jnp.bfloat16, msg=f"d{name}")


def test_flash_lse_residual_matches_oracle():
    """The stash the backward reuses: per-row logsumexp, f32."""
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (2, 64, 4, 32), jnp.float32)
    k = jax.random.normal(k2, (2, 64, 2, 32), jnp.float32)
    v = jax.random.normal(k3, (2, 64, 2, 32), jnp.float32)
    o, lse = fa.flash_attention(q, k, v, causal=True, bq=32, bk=32,
                                interpret=True, return_lse=True)
    np.testing.assert_allclose(
        np.asarray(o),
        np.asarray(fa.flash_attention(q, k, v, causal=True, bq=32, bk=32,
                                      interpret=True)),
        rtol=1e-6, atol=1e-6, err_msg="lse output changed o")
    _close(lse, ref.ref_attention_lse(q, k, v, causal=True))


# ---------------------------------------------------------------------------
# Composition under jax.vjp with non-trivial cotangents


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_composed_pipeline_vjp(dtype):
    """fxp_matmul feeding flash attention, differentiated as one pipeline
    via jax.vjp with a random (non-ones) cotangent."""
    B, S, H, D = 2, 32, 4, 16
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    x = jax.random.normal(k1, (B * S, 48), jnp.float32).astype(dtype)
    wq = _words(k2, (48, 3 * H * D), 8)
    s = jnp.float32(1 / 64)
    cot = jax.random.normal(k4, (B, S, H, D), jnp.float32).astype(dtype)

    def net(x, use_pallas):
        qkv = ops.fxp_matmul(x, wq, s, use_pallas=use_pallas)
        q, k, v = jnp.split(qkv.reshape(B, S, 3 * H, D), 3, axis=2)
        return ops.attention(q, k, v, causal=True, softcap=15.0,
                             use_pallas=use_pallas, bq=16, bk=16)

    out_p, vjp_p = jax.vjp(lambda x: net(x, True), x)
    out_r, vjp_r = jax.vjp(lambda x: net(x, False), x)
    _close(out_p, out_r, dtype=dtype, msg="forward")
    (gx_p,), (gx_r,) = vjp_p(cot), vjp_r(cot)
    assert gx_p.dtype == dtype
    if dtype == jnp.bfloat16:
        # two chained bf16 roundings: small-magnitude elements can sit a
        # few ulps-of-the-tensor-scale apart — compare scale-normalized
        gp, gr = np.asarray(gx_p, np.float32), np.asarray(gx_r, np.float32)
        assert np.abs(gp - gr).max() <= 3e-2 * np.abs(gr).max()
    else:
        _close(gx_p, gx_r, dtype=dtype, msg="dx through the pipeline")


# ---------------------------------------------------------------------------
# No-silent-fallback: the differentiated jaxpr contains fwd AND bwd kernels


def test_attention_grad_jaxpr_has_fwd_and_bwd_kernels():
    q = jnp.zeros((1, 32, 2, 16), jnp.float32)

    def loss(q, use_pallas):
        return jnp.sum(ops.attention(q, q, q, use_pallas=use_pallas))

    jaxpr = jax.make_jaxpr(
        jax.grad(lambda q: loss(q, True)))(q).jaxpr
    assert jaxpr_tools.count_pallas_calls(jaxpr, "_flash_kernel") == 1
    assert jaxpr_tools.count_pallas_calls(jaxpr, "_flash_dq_kernel") == 1
    assert jaxpr_tools.count_pallas_calls(jaxpr, "_flash_dkv_kernel") == 1
    off = jax.make_jaxpr(jax.grad(lambda q: loss(q, False)))(q).jaxpr
    assert jaxpr_tools.count_pallas_calls(off) == 0


def test_fxp_matmul_grad_jaxpr_has_fwd_and_bwd_kernels():
    x = jnp.zeros((32, 64), jnp.float32)
    wq = jnp.zeros((64, 32), jnp.int8)

    def loss(x, use_pallas):
        return jnp.sum(ops.fxp_matmul(x, wq, jnp.float32(0.5),
                                      use_pallas=use_pallas))

    jaxpr = jax.make_jaxpr(jax.grad(lambda x: loss(x, True)))(x).jaxpr
    assert jaxpr_tools.count_pallas_calls(jaxpr, "_fxp_matmul_kernel") == 1
    assert jaxpr_tools.count_pallas_calls(jaxpr, "_matmul_dx_kernel") == 1
    assert jaxpr_tools.count_pallas_calls(jaxpr, "_matmul_dw_kernel") == 1
    off = jax.make_jaxpr(jax.grad(lambda x: loss(x, False)))(x).jaxpr
    assert jaxpr_tools.count_pallas_calls(off) == 0


def _tiny_pallas_cfg(**quant_kw):
    cfg = load_config("tiny")
    quant_kw.setdefault("stochastic_rounding", False)  # same RTN quantize
    return dataclasses.replace(                        # on both dispatches
        cfg,
        quant=dataclasses.replace(cfg.quant, **quant_kw),
        train=dataclasses.replace(cfg.train, adapt_interval=1000,
                                  log_every=1))


def test_train_step_jaxpr_has_fwd_and_bwd_kernels():
    """The acceptance criterion: with quant.use_pallas=True the jitted,
    differentiated train_step contains the flash forward AND backward
    kernels (train_loop._task_loss no longer hard-codes use_pallas=False);
    with False, no pallas_call at all."""
    for on, expect in [(True, 1), (False, 0)]:
        cfg = _tiny_pallas_cfg(use_pallas=on)
        state = train_loop.init_state(cfg)
        batch = train_loop.make_batch(cfg, 0)
        jaxpr = jax.make_jaxpr(train_loop.make_train_step(cfg))(
            state, batch).jaxpr
        for kern in ("_flash_kernel", "_flash_dq_kernel",
                     "_flash_dkv_kernel"):
            n = jaxpr_tools.count_pallas_calls(jaxpr, kern)
            assert n == expect, (on, kern, n)
        if not on:
            assert jaxpr_tools.count_pallas_calls(jaxpr) == 0


# ---------------------------------------------------------------------------
# End-to-end train-step parity: the dispatch flip must not change numerics


def test_train_trajectory_parity_pallas_vs_xla():
    """A few real optimizer steps on the tiny transformer: loss and
    grad-norm trajectories with use_pallas=True (interpret kernels, custom
    VJPs) vs False (pure XLA) agree within float tolerance. SR is disabled
    so both dispatches quantize identically (the noise streams differ by
    design); what's under test is the differentiated forward."""
    hist = {}
    for on in (False, True):
        cfg = _tiny_pallas_cfg(use_pallas=on)
        state = train_loop.init_state(cfg)
        step = jax.jit(train_loop.make_train_step(cfg))
        rows = []
        for i in range(4):
            state, metrics = step(state, train_loop.make_batch(cfg, i))
            rows.append((float(metrics["loss"]),
                         float(metrics["grad_norm"])))
        hist[on] = rows
    for (l_x, g_x), (l_p, g_p) in zip(hist[False], hist[True]):
        np.testing.assert_allclose(l_p, l_x, rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(g_p, g_x, rtol=2e-2, atol=2e-2)
