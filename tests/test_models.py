"""Per-architecture smoke tests + model-level consistency properties."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.config import load_config
from repro.configs import assigned_archs, get_smoke_config
from repro.models import ssm, transformer
from repro.serve.engine import _merge_prefill_caches

ARCHS = assigned_archs()


def _inputs(m, B=2, S=16, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    kw = {}
    if m.is_encoder:
        kw["embeds"] = jax.random.normal(key, (B, S, m.d_model))
    else:
        kw["tokens"] = jax.random.randint(key, (B, S), 0, m.vocab_size)
    if m.cross_attn_every:
        kw["memory"] = jax.random.normal(
            key, (B, m.num_image_tokens, m.d_model))
    return kw


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_and_finite(arch):
    m = get_smoke_config(arch).model
    params = transformer.init_params(jax.random.PRNGKey(0), m)
    B, S = 2, 16
    logits = transformer.forward(params, m, **_inputs(m, B, S))
    assert logits.shape == (B, S, m.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    """One quantized train step on the reduced config: loss finite, params
    move, no NaNs anywhere in the state."""
    from repro.train import train_loop
    cfg = get_smoke_config(arch)
    cfg = dataclasses.replace(
        cfg, train=dataclasses.replace(cfg.train, seq_len=16, global_batch=2))
    state = train_loop.init_state(cfg)
    step = jax.jit(train_loop.make_train_step(cfg))
    batch = train_loop.make_batch(cfg, 0)
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    for leaf in jax.tree_util.tree_leaves(new_state["params"]):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree_util.tree_leaves(state["params"]),
                        jax.tree_util.tree_leaves(new_state["params"])))
    assert moved


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if not get_smoke_config(a).model.is_encoder])
def test_decode_matches_forward(arch):
    m = get_smoke_config(arch).model
    if m.num_experts:  # compare dropless-to-dropless
        m = dataclasses.replace(m, capacity_factor=16.0)
    params = transformer.init_params(jax.random.PRNGKey(0), m)
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, m.vocab_size)
    kw = {}
    if m.cross_attn_every:
        kw["memory"] = jax.random.normal(jax.random.PRNGKey(2),
                                         (B, m.num_image_tokens, m.d_model))
    full = transformer.forward(params, m, tokens=toks, **kw)
    caches = transformer.init_caches(m, B, S, dtype=jnp.float32)
    if m.cross_attn_every:
        from repro.models import attention
        plan, np_ = transformer.build_plan(m)
        for i, slot in enumerate(plan):
            if slot.kind == "cross":
                key_name = transformer.slot_key(i, slot)
                ks, vs = [], []
                for pidx in range(np_):
                    p = jax.tree.map(lambda a: a[pidx],
                                     params["blocks"][key_name])
                    k_, v_ = attention.project_memory(
                        p, kw["memory"].astype(jnp.bfloat16), m)
                    ks.append(k_)
                    vs.append(v_)
                caches[key_name] = {"k": jnp.stack(ks).astype(jnp.float32),
                                    "v": jnp.stack(vs).astype(jnp.float32)}
    outs = []
    for t in range(S):
        logits, caches = transformer.decode_step(params, m, toks[:, t],
                                                 caches, jnp.int32(t))
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    # bf16 blockwise compute: tolerance scales with how much the decode path
    # re-orders accumulations (mamba recurrence, MoE dispatch, cross-attn)
    if any(k == "mamba" for k in m.layer_pattern):
        tol = 0.15
    else:
        tol = 0.05   # bf16 block compute: contraction order differs between
                     # the batched forward and the step-wise decode einsums
    assert float(jnp.max(jnp.abs(dec - full))) < tol


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if not get_smoke_config(a).model.is_encoder
                                  and not get_smoke_config(a).model.cross_attn_every])
def test_prefill_then_decode(arch):
    m = get_smoke_config(arch).model
    if m.num_experts:
        m = dataclasses.replace(m, capacity_factor=16.0)
    params = transformer.init_params(jax.random.PRNGKey(0), m)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              m.vocab_size)
    full = transformer.forward(params, m, tokens=toks)
    logits_pref, pref = transformer.prefill(params, m, toks[:, :S],
                                            cache_dtype=jnp.float32)
    assert float(jnp.max(jnp.abs(logits_pref - full[:, S - 1]))) < 0.1
    gen = transformer.init_caches(m, B, S + 4, dtype=jnp.float32)
    gen = _merge_prefill_caches(gen, pref, S)
    logits_dec, _ = transformer.decode_step(params, m, toks[:, S], gen,
                                            jnp.int32(S))
    assert float(jnp.max(jnp.abs(logits_dec - full[:, S]))) < 0.1


def test_ssd_chunked_equals_recurrent():
    cfg = get_smoke_config("mamba2-780m").model
    p = jax.tree.map(lambda a: a[0], ssm.init_layer(jax.random.PRNGKey(1),
                                                    cfg, 1))
    B, S = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model),
                          jnp.float32)
    ref, final_cache = ssm.apply(p, x, cfg, return_state=True)
    cache = jax.tree.map(lambda a: a[0],
                         ssm.init_cache(cfg, B, 1, dtype=jnp.float32))
    outs = []
    for t in range(S):
        y, cache = ssm.apply_decode(p, x[:, t:t + 1], cfg, cache)
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    assert float(jnp.max(jnp.abs(dec - ref))) < 1e-4
    # state handoff: chunked final state == recurrent final state
    assert float(jnp.max(jnp.abs(cache["ssm"] - final_cache["ssm"]))) < 1e-4


@pytest.mark.parametrize("s", [5, 8, 13, 16, 24])
def test_ssd_chunk_boundary_independence(s):
    """Chunked SSD result must not depend on the chunk size (pads included)."""
    cfg = get_smoke_config("mamba2-780m").model
    p = jax.tree.map(lambda a: a[0], ssm.init_layer(jax.random.PRNGKey(1),
                                                    cfg, 1))
    x = jax.random.normal(jax.random.PRNGKey(3), (1, s, cfg.d_model))
    outs = []
    for chunk in (4, 8, 64):
        c = dataclasses.replace(cfg, ssm_chunk=chunk)
        outs.append(ssm.apply(p, x, c))
    for o in outs[1:]:
        assert float(jnp.max(jnp.abs(o - outs[0]))) < 1e-4


def test_plan_periodicity():
    checks = {
        "granite-8b": (1, 36), "gemma2-2b": (2, 13), "zamba2-7b": (3, 27),
        "mamba2-780m": (1, 48), "mixtral-8x22b": (1, 56),
        "llama-3.2-vision-11b": (5, 8), "hubert-xlarge": (1, 48),
    }
    for arch, (period, np_) in checks.items():
        m = load_config(arch).model
        plan, got_np = transformer.build_plan(m)
        assert (len(plan), got_np) == (period, np_), arch


def test_full_configs_match_assignment():
    spec = {
        "granite-8b": dict(num_layers=36, d_model=4096, num_heads=32,
                           num_kv_heads=8, d_ff=14336, vocab_size=49152),
        "gemma2-2b": dict(num_layers=26, d_model=2304, num_heads=8,
                          num_kv_heads=4, d_ff=9216, vocab_size=256000),
        "llama3.2-3b": dict(num_layers=28, d_model=3072, num_heads=24,
                            num_kv_heads=8, d_ff=8192, vocab_size=128256),
        "smollm-360m": dict(num_layers=32, d_model=960, num_heads=15,
                            num_kv_heads=5, d_ff=2560, vocab_size=49152),
        "zamba2-7b": dict(num_layers=81, d_model=3584, num_heads=32,
                          num_kv_heads=32, d_ff=14336, vocab_size=32000,
                          ssm_state=64),
        "mixtral-8x22b": dict(num_layers=56, d_model=6144, num_heads=48,
                              num_kv_heads=8, d_ff=16384, vocab_size=32768,
                              num_experts=8, experts_per_token=2),
        "arctic-480b": dict(num_layers=35, d_model=7168, num_heads=56,
                            num_kv_heads=8, d_ff=4864, vocab_size=32000,
                            num_experts=128, experts_per_token=2),
        "llama-3.2-vision-11b": dict(num_layers=40, d_model=4096,
                                     num_heads=32, num_kv_heads=8,
                                     d_ff=14336, vocab_size=128256),
        "hubert-xlarge": dict(num_layers=48, d_model=1280, num_heads=16,
                              num_kv_heads=16, d_ff=5120, vocab_size=504),
        "mamba2-780m": dict(num_layers=48, d_model=1536, vocab_size=50280,
                            ssm_state=128),
    }
    for arch, fields in spec.items():
        m = load_config(arch).model
        for k, v in fields.items():
            assert getattr(m, k) == v, f"{arch}.{k}: {getattr(m, k)} != {v}"
