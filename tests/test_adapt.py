"""AdaPT algorithm invariants — parametrized property sweeps (the container
has no `hypothesis`, so properties run over seeded input grids)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import QuantConfig
from repro.core import controller, fixed_point as fxp, pushdown, pushup

SEEDS = [0, 1, 2, 3]


# ---------------------------------------------------------------------------
# fixed-point quantizer properties


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("wl,fl", [(8, 4), (4, 2), (16, 12), (2, 0), (12, 8)])
def test_quantize_on_grid_and_bounded(seed, wl, fl):
    w = jax.random.normal(jax.random.PRNGKey(seed), (512,)) * 3.0
    q = fxp.quantize(w, wl, fl)
    scaled = np.asarray(q) * 2.0 ** fl
    assert np.allclose(scaled, np.round(scaled), atol=1e-4), "not on grid"
    qmin, qmax = -(2 ** (wl - 1)), 2 ** (wl - 1) - 1
    assert scaled.min() >= qmin - 1e-4 and scaled.max() <= qmax + 1e-4


@pytest.mark.parametrize("seed", SEEDS)
def test_quantize_idempotent(seed):
    w = jax.random.normal(jax.random.PRNGKey(seed), (256,))
    q1 = fxp.quantize(w, 8, 4)
    q2 = fxp.quantize(q1, 8, 4)
    assert float(jnp.max(jnp.abs(q1 - q2))) == 0.0


@pytest.mark.parametrize("seed", SEEDS)
def test_stochastic_rounding_unbiased(seed):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (64,))
    reps = 512
    qs = []
    for i in range(reps):
        u = jax.random.uniform(jax.random.fold_in(key, i), w.shape)
        qs.append(fxp.quantize(w, 8, 4, u=u))
    bias = jnp.abs(jnp.mean(jnp.stack(qs), 0) - jnp.clip(
        w, -(2.0 ** 3), 2.0 ** 3 - 2.0 ** -4))
    # SR is unbiased on the representable range; grid step is 2^-4
    assert float(jnp.max(bias)) < 3 * (2.0 ** -4) / np.sqrt(reps) * 4


def test_wider_word_never_further():
    """Monotone refinement: quantization error shrinks (weakly) with WL at
    fixed representable range."""
    w = jax.random.normal(jax.random.PRNGKey(7), (2048,))
    amax = jnp.max(jnp.abs(w))
    errs = []
    for wl in (4, 6, 8, 12, 16, 20):
        fl = fxp.fl_for_wl(amax, wl)
        errs.append(float(jnp.mean(jnp.abs(fxp.quantize(w, wl, fl) - w))))
    assert all(a >= b - 1e-9 for a, b in zip(errs, errs[1:])), errs


# ---------------------------------------------------------------------------
# PushDown (KL) properties


def test_kl_zero_for_identical():
    w = jax.random.normal(jax.random.PRNGKey(0), (4096,))
    h = pushdown._histogram(w, jnp.min(w), jnp.max(w), jnp.int32(100), 150)
    assert float(pushdown.kl_bits(h, h)) < 1e-6


def test_pushdown_finds_exact_representation():
    """Weights already on a coarse grid ⇒ PushDown returns a small WL."""
    key = jax.random.PRNGKey(1)
    w = fxp.quantize(jax.random.normal(key, (8192,)), 5, 3)
    wl, fl = pushdown.push_down(w, jnp.int32(100), r_upr=150, eps_kl=1e-2)
    assert int(wl) <= 8, f"grid-aligned tensor got WL={int(wl)}"


def test_pushdown_wide_for_heavy_tailed():
    """A distribution with fine structure needs more bits than a coarse one."""
    key = jax.random.PRNGKey(2)
    fine = jax.random.normal(key, (8192,)) * jnp.exp(
        jax.random.normal(jax.random.fold_in(key, 1), (8192,)))
    coarse = fxp.quantize(jax.random.normal(key, (8192,)), 4, 2)
    wl_fine, _ = pushdown.push_down(fine, jnp.int32(150), r_upr=150,
                                    eps_kl=1e-2)
    wl_coarse, _ = pushdown.push_down(coarse, jnp.int32(150), r_upr=150,
                                      eps_kl=1e-2)
    assert int(wl_fine) >= int(wl_coarse)


@pytest.mark.parametrize("seed", SEEDS)
def test_pushdown_subsample_stable(seed):
    """The strided-subsample estimate stays within ±4 bits of full-tensor."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (1 << 16,))
    wl_full, _ = pushdown.push_down(w, jnp.int32(100), r_upr=150, eps_kl=1e-2)
    sub = pushdown.subsample(w, 4096)
    wl_sub, _ = pushdown.push_down(sub, jnp.int32(100), r_upr=150,
                                   eps_kl=1e-2)
    assert abs(int(wl_full) - int(wl_sub)) <= 4


# ---------------------------------------------------------------------------
# PushUp properties


@pytest.mark.parametrize("ds", [1.0, 1.5, 2.0, 5.0, 50.0])
@pytest.mark.parametrize("st", [0, 1, 2])
def test_pushup_bounds(ds, st):
    wl, fl = pushup.push_up(jnp.int32(6), jnp.int32(3), jnp.float32(ds),
                            jnp.int32(st), buff=4, max_wl=32)
    assert 2 <= int(wl) <= 32
    assert 0 <= int(fl) < int(wl)


def test_pushup_strategy_ordering():
    """min ≤ mean ≤ max suggestion at the same diversity."""
    ds = jnp.float32(8.0)
    outs = [int(pushup.push_up(jnp.int32(6), jnp.int32(3), ds, jnp.int32(s),
                               buff=4)[1]) for s in (0, 1, 2)]
    assert outs[0] <= outs[1] <= outs[2], outs


def test_gradient_diversity_lower_bound():
    """Δs ≥ 1 (triangle inequality) on random windows."""
    key = jax.random.PRNGKey(0)
    for i in range(8):
        g = jax.random.normal(jax.random.fold_in(key, i), (16, 64))
        norm_sum = jnp.sum(jnp.linalg.norm(g, axis=1))
        sum_norm = jnp.linalg.norm(jnp.sum(g, axis=0))
        assert float(pushup.gradient_diversity(norm_sum, sum_norm)) >= 1 - 1e-5


def test_adapt_strategy_transitions():
    # improving loss → min; stagnating → escalate
    assert int(pushup.adapt_strategy(jnp.int32(1), jnp.float32(2.0),
                                     jnp.float32(1.0))) == pushup.ST_MIN
    assert int(pushup.adapt_strategy(jnp.int32(0), jnp.float32(1.0),
                                     jnp.float32(2.0))) == 1
    assert int(pushup.adapt_strategy(jnp.int32(2), jnp.float32(1.0),
                                     jnp.float32(2.0))) == pushup.ST_MAX


def test_lookback_and_resolution_bounds():
    q = QuantConfig()
    for ds in (0.5, 1.0, 3.0, 1e6, float("inf")):
        lb = pushup.adapt_lookback(jnp.int32(50), jnp.float32(ds),
                                   lb_lwr=q.lb_lwr, lb_upr=q.lb_upr,
                                   gamma=q.gamma)
        assert q.lb_lwr <= int(lb) <= q.lb_upr
        r = pushup.adapt_resolution(jnp.int32(100), lb, lb_lwr=q.lb_lwr,
                                    lb_upr=q.lb_upr, r_lwr=q.r_lwr,
                                    r_upr=q.r_upr)
        assert q.r_lwr <= int(r) <= q.r_upr


# ---------------------------------------------------------------------------
# controller integration


def _tiny_params(key):
    return {"blocks": {"mlp": {"w": jax.random.normal(key, (2, 16, 16))}},
            "head": jax.random.normal(jax.random.fold_in(key, 1), (16, 32))}


def test_controller_window_and_switch():
    qcfg = dataclasses.replace(QuantConfig(), lb_lwr=3, lb_upr=5)
    params = _tiny_params(jax.random.PRNGKey(0))
    st = controller.init_adapt_state(params, qcfg)
    assert set(st["tensors"]) == {"blocks/mlp/w", "head"}
    assert st["tensors"]["blocks/mlp/w"]["wl"].shape == (2,)   # per layer
    g = jax.tree.map(lambda p: jnp.ones_like(p) * 0.1, params)
    for i in range(3):
        st = controller.accumulate(st, g, jnp.float32(1.0 - i * 0.1))
    assert int(st["tensors"]["head"]["count"]) == 3
    st2 = controller.precision_switch(st, params, qcfg)
    # window full (count >= lb_lwr=3): counters reset, precision updated
    assert int(st2["tensors"]["head"]["count"]) == 0
    wl = st2["tensors"]["head"]["wl"]
    assert 2 <= int(wl) <= 32


def test_quantize_params_respects_exclusions():
    qcfg = QuantConfig()
    params = {"blocks": {"attn": {"wq": jnp.ones((2, 8, 8)),
                                  "pre_norm": jnp.ones((2, 8))},
                         "moe": {"router": jnp.ones((2, 8, 4))}}}
    st = controller.init_adapt_state(params, qcfg)
    assert "blocks/attn/wq" in st["tensors"]
    assert "blocks/attn/pre_norm" not in st["tensors"]   # ndim < 2 rule + name
    assert "blocks/moe/router" not in st["tensors"]      # excluded by name
    q = controller.quantize_params(params, st, qcfg,
                                   key=jax.random.PRNGKey(0))
    # router passes through exactly
    assert float(jnp.max(jnp.abs(q["blocks"]["moe"]["router"] - 1.0))) == 0.0


def test_precision_switch_is_jittable_and_stable():
    qcfg = dataclasses.replace(QuantConfig(), lb_lwr=2, lb_upr=4)
    params = _tiny_params(jax.random.PRNGKey(3))
    st = controller.init_adapt_state(params, qcfg)
    g = jax.tree.map(lambda p: jnp.ones_like(p) * 0.01, params)
    st = controller.accumulate(st, g, jnp.float32(1.0))
    st = controller.accumulate(st, g, jnp.float32(0.9))
    fn = jax.jit(lambda s, p: controller.precision_switch(s, p, qcfg))
    st2 = fn(st, params)
    for ts in st2["tensors"].values():
        assert bool(jnp.all(ts["fl"] < ts["wl"]))
        assert bool(jnp.all(ts["wl"] <= qcfg.max_wl))
