"""Dense-layer kernel-path suite: the quantized kernels as the MODEL's
default data path (not a sidecar).

Covers, bottom-up:
  * the quantize-prologue kernels (``fxp_qmatmul`` / ``matmul_qdx``):
    SR words bit-identical to the materialized ``sr_quantize_fused_int8``
    stream on 2-D leaves, RTN bit-identical to ``jnp.round``, fwd/grad
    parity vs XLA autodiff of the straight-through oracle across odd /
    prime / multi-block shapes;
  * the straight-through dense VJPs (``fxp_dense_vjp`` / ``fxp_qdense_vjp``):
    dw = xᵀ@dy lands whole on the master receiver, scale cotangent zero;
  * controller emission: dense-consumed leaves become prologue dicts under
    use_pallas + dense_prologue (packed dicts otherwise), non-dense leaves
    keep the materialized container; unpack_tree(keep_dense=...) and
    strip_packed_grads agree on both flavors;
  * the acceptance criteria: a jitted tiny-config train step lowers EVERY
    dense layer (7 in-scan + head) to Pallas fwd+dx+dw with ZERO
    dequantized-weight XLA matmuls (jaxpr-asserted), loss/grad-norm
    trajectory parity vs the XLA dispatch within the
    test_vjp_differential.py tolerances, and the prologue variant still
    fires on steps traced after a precision switch;
  * the serve path: Engine over the packed tree, RTN words shared with
    training, finite logits.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import jaxpr_tools
from repro.config import ModelConfig, load_config
from repro.core import controller
from repro.core import fixed_point as fxp
from repro.kernels import fxp_matmul as fm
from repro.kernels import ops, ref
from repro.train import train_loop

KEY = jax.random.PRNGKey(7)

TOL = dict(rtol=2e-4, atol=2e-4)


def _close(got, want, msg="", tol=TOL):
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               **tol, err_msg=msg)


# ---------------------------------------------------------------------------
# Quantize-prologue kernels


@pytest.mark.parametrize("m,k,n", [(16, 32, 16), (37, 53, 29),
                                   (127, 257, 131)])
@pytest.mark.parametrize("fl", [0, 4, 7])
def test_fxp_qmatmul_words_match_materialized(m, k, n, fl):
    """The prologue's SR word draw for a 2-D master is bit-identical to
    ``sr_quantize_fused_int8``'s PORTABLE stream (the one CPU CI runs):
    quantize-in-prologue and materialize-then-matmul are the same function
    of ⟨master, seed, FL⟩ wherever both draw portably. (Compiled TPU
    materialized words use the hardware PRNG — same distribution only.)"""
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, m * 31 + fl))
    x = jax.random.normal(k1, (m, k), jnp.float32)
    w = jax.random.normal(k2, (k, n), jnp.float32)
    seed = jnp.int32(m * 1009 + fl)
    wq = ref.ref_sr_quantize_fused_int8_words(w, seed, fl)
    want = ref.ref_fxp_matmul(x, wq, jnp.ldexp(jnp.float32(1.0), -fl))
    got = fm.fxp_qmatmul(x, w, seed, jnp.int32(fl), jnp.int32(1),
                         bm=32, bn=32, bk=32, interpret=True)
    _close(got, want, msg=f"fl={fl}")


def test_fxp_qmatmul_rtn_matches_round():
    """mode=0 is round-half-even — bit-identical words to the XLA packed
    path's ``jnp.round`` (ties included: the half-integer grid points)."""
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (24, 48), jnp.float32)
    w = jax.random.normal(k2, (48, 40), jnp.float32)
    # force exact ties onto the 2^-FL half grid for a few entries
    w = w.at[0, :8].set(jnp.arange(8, dtype=jnp.float32) / 16.0 + 1.0 / 32.0)
    fl = jnp.int32(4)
    wq = jnp.clip(jnp.round(w * 16.0), -128, 127).astype(jnp.int8)
    want = ref.ref_fxp_matmul(x, wq, jnp.float32(1 / 16))
    got = fm.fxp_qmatmul(x, w, jnp.int32(0), fl, jnp.int32(0),
                         bm=16, bn=16, bk=16, interpret=True)
    _close(got, want)


@pytest.mark.parametrize("m,k,n", [(16, 32, 16), (37, 53, 29),
                                   (100, 70, 50)])
@pytest.mark.parametrize("mode", [0, 1])
def test_qdense_grad_parity(m, k, n, mode):
    """jax.grad through the prologue VJP vs XLA autodiff of the
    straight-through oracle: dx via the dequantized words, dw = xᵀ@dy."""
    k1, k2, k3 = jax.random.split(jax.random.fold_in(KEY, m + mode), 3)
    x = jax.random.normal(k1, (m, k), jnp.float32)
    w = jax.random.normal(k2, (k, n), jnp.float32)
    cot = jax.random.normal(k3, (m, n), jnp.float32)
    seed, fl = jnp.int32(99), jnp.int32(5)

    gp = jax.grad(lambda x, w: jnp.sum(
        ops.fxp_qdense(x, w, seed, fl, jnp.int32(mode), use_pallas=True)
        * cot), (0, 1))(x, w)
    gr = jax.grad(lambda x, w: jnp.sum(
        ref.ref_fxp_qdense(x, w, seed, fl, mode) * cot), (0, 1))(x, w)
    _close(gp[0], gr[0], msg=f"dx mode={mode}")
    _close(gp[1], gr[1], msg=f"dw mode={mode}")
    # the straight-through dw is exactly xᵀ@dy
    _close(gp[1], ref.ref_matmul_dw(x, cot), msg="dw straight-through")


def test_qdense_fwd_bwd_word_agreement_multiblock():
    """fwd and dx tile the weight DIFFERENTLY (K- vs N-innermost grids);
    the index-hash stream must give them identical words anyway — dx from
    the Pallas VJP equals dy @ dequant(words)ᵀ of the forward's words."""
    k1, k2, k3 = jax.random.split(KEY, 3)
    x = jax.random.normal(k1, (64, 96), jnp.float32)
    w = jax.random.normal(k2, (96, 80), jnp.float32)
    cot = jax.random.normal(k3, (64, 80), jnp.float32)
    seed, fl = jnp.int32(3), jnp.int32(6)
    gx = jax.grad(lambda x: jnp.sum(
        fm.fxp_qdense_vjp(x, w, seed, fl, jnp.int32(1), bm=32, bn=16,
                          bk=32, interpret=True) * cot))(x)
    wq = ref.ref_sr_quantize_fused_int8_words(w, seed, 6)
    want = jnp.dot(cot, (wq.astype(jnp.float32) / 64.0).T)
    _close(gx, want)


def test_fxp_dense_grad_straight_through():
    """Materialized-words dense VJP: dwref = xᵀ@dy (whole, cast to the
    receiver dtype), dscale = 0 (controller state), dx streams int8."""
    k1, k2, k3 = jax.random.split(KEY, 3)
    x = jax.random.normal(k1, (40, 56), jnp.float32)
    wq = jax.random.randint(k2, (56, 24), -128, 128, jnp.int8)
    cot = jax.random.normal(k3, (40, 24), jnp.float32)
    sc = jnp.bfloat16(1 / 32)
    wref = jnp.zeros((56, 24), jnp.bfloat16)
    gx, gs, gr = jax.grad(lambda x, s, r: jnp.sum(
        fm.fxp_dense_vjp(x, wq, s, r, interpret=True) * cot),
        (0, 1, 2))(x, sc, wref)
    _close(gx, ref.ref_matmul_dx(cot, wq, jnp.float32(1 / 32)))
    assert float(jnp.asarray(gs, jnp.float32)) == 0.0
    assert gr.dtype == jnp.bfloat16
    _close(gr, ref.ref_matmul_dw(x, cot), tol=dict(rtol=3e-2, atol=3e-2))


def test_dense_vjp_jaxpr_kernels():
    """Differentiated op-level jaxprs contain the expected fwd + bwd
    Pallas kernels (and the prologue pair for the qdense flavor)."""
    x = jnp.zeros((32, 64), jnp.float32)
    wq = jnp.zeros((64, 32), jnp.int8)
    w = jnp.zeros((64, 32), jnp.float32)
    wref = jnp.zeros((64, 32), jnp.bfloat16)

    j1 = jax.make_jaxpr(jax.grad(lambda x: jnp.sum(ops.fxp_dense(
        x, wq, jnp.float32(0.5), wref, use_pallas=True))))(x).jaxpr
    assert jaxpr_tools.count_pallas_calls(j1, "_fxp_matmul_kernel") == 1
    assert jaxpr_tools.count_pallas_calls(j1, "_matmul_dx_kernel") == 1
    assert jaxpr_tools.count_pallas_calls(j1, "_matmul_dw_kernel") == 1

    j2 = jax.make_jaxpr(jax.grad(lambda x: jnp.sum(ops.fxp_qdense(
        x, w, jnp.int32(1), jnp.int32(4), jnp.int32(1),
        use_pallas=True))))(x).jaxpr
    assert jaxpr_tools.count_pallas_calls(j2, "_fxp_qmatmul_kernel") == 1
    assert jaxpr_tools.count_pallas_calls(j2, "_matmul_qdx_kernel") == 1
    assert jaxpr_tools.count_pallas_calls(j2, "_matmul_dw_kernel") == 1


# ---------------------------------------------------------------------------
# Controller emission + unpack/strip round trip


def _tiny_packed_cfg(prologue, use_pallas=True, sr=True, interval=1000):
    cfg = load_config("tiny", overrides=[
        "quant.container_dtype=int8_packed", "quant.max_wl=8",
        "quant.init_wl=8", "quant.init_fl=4",
        f"quant.stochastic_rounding={'true' if sr else 'false'}"])
    return dataclasses.replace(
        cfg,
        quant=dataclasses.replace(cfg.quant, use_pallas=use_pallas,
                                  dense_prologue=prologue),
        train=dataclasses.replace(cfg.train, adapt_interval=interval,
                                  log_every=1))


def test_controller_emits_prologue_leaves():
    cfg = _tiny_packed_cfg(prologue=True)
    state = train_loop.init_state(cfg)
    qp = controller.quantize_params_packed(state["params"], state["adapt"],
                                           cfg.quant, key=KEY)
    blocks = qp["blocks"]
    # stacked dense leaf → prologue dict with (L,) metadata
    wq = blocks["s0_attn"]["wq"]
    assert fxp.is_qdense(wq)
    L = state["params"]["blocks"]["s0_attn"]["wq"].shape[0]
    assert wq["seed"].shape == wq["flq"].shape == wq["mode"].shape == (L,)
    assert int(wq["mode"][0]) == 1                     # SR mode
    # per-layer seeds differ (folded layer index)
    assert int(wq["seed"][0]) != int(wq["seed"][1])
    # unstacked dense leaf (head) → prologue dict with scalar metadata
    assert fxp.is_qdense(qp["head"]) and qp["head"]["flq"].shape == ()
    # non-dense quantized leaf (embed) keeps the materialized container
    assert fxp.is_packed(qp["embed"])
    # RTN (serving / SR off): mode 0
    qp_r = controller.quantize_params_packed(state["params"], state["adapt"],
                                             cfg.quant, key=None)
    assert int(qp_r["head"]["mode"]) == 0


def test_prologue_excludes_sharded_leaves():
    """An explicitly-sharded dense leaf must NOT become a prologue dict
    (pallas_call has no SPMD rule — a mesh would gather the f32 master
    into every launch); it keeps the 1-byte packed container. Replicated
    placements stay eligible."""
    import numpy as np_
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    cfg = _tiny_packed_cfg(prologue=True)
    state = train_loop.init_state(cfg)
    mesh = Mesh(np_.array(jax.devices()[:1]), ("data",))
    shardings = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), state["params"])
    head_sharded = dict(shardings)
    head_sharded["head"] = NamedSharding(mesh, P("data", None))
    qp = controller.quantize_params_packed(
        state["params"], state["adapt"], cfg.quant, key=KEY,
        shardings=head_sharded)
    assert fxp.is_packed(qp["head"])           # sharded → materialized
    assert fxp.is_qdense(qp["blocks"]["s0_attn"]["wq"])  # replicated → ok


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs a multi-device mesh (the multidevice-4 "
                           "CI entry forces 4 host devices)")
def test_packed_dense_sharded_mesh_refused():
    """A dense leaf sharded over a REAL (>1-device) mesh under use_pallas
    must refuse loudly: the dense kernels cannot be partitioned by GSPMD,
    so proceeding would silently replicate every launch (all-gathering
    operands) — the opposite of what the packed container exists for."""
    import numpy as np_
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    cfg = _tiny_packed_cfg(prologue=False)
    state = train_loop.init_state(cfg)
    mesh = Mesh(np_.array(jax.devices()[:2]), ("data",))
    shardings = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), state["params"])
    shardings["head"] = NamedSharding(mesh, P("data", None))
    with pytest.raises(ValueError, match="dense kernel path"):
        controller.quantize_params_packed(
            state["params"], state["adapt"], cfg.quant, key=KEY,
            shardings=shardings)
    # the guard is generic over Sharding types, not a NamedSharding
    # whitelist — a PositionalSharding distribution must refuse too
    from jax.sharding import PositionalSharding
    shardings["head"] = PositionalSharding(jax.devices()[:2]).reshape(2, 1)
    with pytest.raises(ValueError, match="dense kernel path"):
        controller.quantize_params_packed(
            state["params"], state["adapt"], cfg.quant, key=KEY,
            shardings=shardings)


def test_unpack_and_strip_both_flavors():
    cfg = _tiny_packed_cfg(prologue=True)
    state = train_loop.init_state(cfg)
    qp = controller.quantize_params_packed(state["params"], state["adapt"],
                                           cfg.quant, key=KEY)
    kept = fxp.unpack_tree(qp, keep_dense=True)
    assert fxp.is_qdense(kept["head"])                 # dense rides through
    assert not fxp.is_packed(kept["embed"])            # non-dense unpacked
    full = fxp.unpack_tree(qp)
    h = qp["head"]
    want = (ref.ref_qdense_words(h["wm"], h["seed"], h["flq"], h["mode"])
            .astype(jnp.float32) * jnp.ldexp(jnp.float32(1.0), -h["flq"]))
    _close(full["head"], want, msg="qdense_view == dequant of stream words")
    # strip: qdense grads land on wm, packed grads on wref
    fake = jax.tree_util.tree_map(jnp.ones_like, qp)
    stripped = controller.strip_packed_grads(fake)
    assert stripped["head"].shape == state["params"]["head"].shape
    assert stripped["embed"].shape == state["params"]["embed"].shape


# ---------------------------------------------------------------------------
# Acceptance: jitted train step lowers every dense layer to Pallas
# fwd+dx+dw with zero dequantized-weight XLA matmuls


def _dense_weight_shapes(params):
    """All 2-D shapes a dequantized dense weight (or its transpose) could
    present to an XLA dot in the scan body / head matmul."""
    shapes = set()
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in flat:
        p = controller.path_str(path)
        if not fxp.is_dense_param(p) or leaf.ndim not in (2, 3):
            continue
        s = leaf.shape[-2:]
        shapes.add(s)
        shapes.add(s[::-1])
    return shapes


# 7 dense layers in the scanned block (wq wk wv wo wi_gate wi_up wo) + head
N_DENSE = 8


@pytest.mark.parametrize("prologue", [False, True])
def test_train_step_lowers_all_dense_layers(prologue):
    cfg = _tiny_packed_cfg(prologue)
    state = train_loop.init_state(cfg)
    batch = train_loop.make_batch(cfg, 0)
    jaxpr = jax.make_jaxpr(train_loop.make_train_step(cfg))(
        state, batch).jaxpr
    fwd = "_fxp_qmatmul_kernel" if prologue else "_fxp_matmul_kernel"
    dx = "_matmul_qdx_kernel" if prologue else "_matmul_dx_kernel"
    for kern in (fwd, dx, "_matmul_dw_kernel"):
        n = jaxpr_tools.count_pallas_calls(jaxpr, kern)
        assert n == N_DENSE, (kern, n)
    # the OTHER flavor is absent — no double dispatch
    other = "_fxp_matmul_kernel" if prologue else "_fxp_qmatmul_kernel"
    assert jaxpr_tools.count_pallas_calls(jaxpr, other) == 0
    # zero dequantized-weight XLA matmuls: no float dot consumes a tensor
    # of a dense weight's (or its transpose's) shape
    forbidden = _dense_weight_shapes(state["params"])
    bad = [(l, r, dt) for l, r, dt in jaxpr_tools.dot_general_shapes(jaxpr)
           if r in forbidden and dt != jnp.int8]
    assert not bad, bad


def test_train_step_xla_dispatch_has_no_dense_kernels():
    cfg = _tiny_packed_cfg(prologue=False, use_pallas=False)
    state = train_loop.init_state(cfg)
    batch = train_loop.make_batch(cfg, 0)
    jaxpr = jax.make_jaxpr(train_loop.make_train_step(cfg))(
        state, batch).jaxpr
    assert jaxpr_tools.count_pallas_calls(jaxpr) == 0
    # ... and the dequantized dots ARE there (the contrast that makes the
    # zero-dequantized-matmul assertion above meaningful)
    forbidden = _dense_weight_shapes(state["params"])
    hits = [r for _, r, dt in jaxpr_tools.dot_general_shapes(jaxpr)
            if r in forbidden and dt != jnp.int8]
    assert hits


def test_train_trajectory_parity_dense_kernels_vs_xla():
    """4 real optimizer steps, SR off (RTN words are bit-identical across
    all three dispatches): loss/grad-norm trajectories agree within the
    test_vjp_differential.py tolerances."""
    hist = {}
    for name, (up, pro) in {"xla": (False, False), "mat": (True, False),
                            "pro": (True, True)}.items():
        cfg = _tiny_packed_cfg(pro, use_pallas=up, sr=False)
        state = train_loop.init_state(cfg)
        step = jax.jit(train_loop.make_train_step(cfg))
        rows = []
        for i in range(4):
            state, m = step(state, train_loop.make_batch(cfg, i))
            rows.append((float(m["loss"]), float(m["grad_norm"])))
        hist[name] = rows
    for variant in ("mat", "pro"):
        for (l_x, g_x), (l_p, g_p) in zip(hist["xla"], hist[variant]):
            np.testing.assert_allclose(l_p, l_x, rtol=2e-3, atol=2e-3)
            np.testing.assert_allclose(g_p, g_x, rtol=2e-2, atol=2e-2)


def test_prologue_fires_across_precision_switch():
    """Steps traced before AND after a precision switch keep the prologue
    kernels (freshly re-quantized layers never materialize words in HBM:
    the new ⟨WL,FL⟩ flows in as data, the graph — and its Pallas calls —
    never change), and training stays finite through the switch."""
    cfg = _tiny_packed_cfg(prologue=True, interval=2)
    state = train_loop.init_state(cfg)
    step = jax.jit(train_loop.make_train_step(cfg))
    switch = jax.jit(train_loop.make_precision_switch(cfg))
    for i in range(5):
        state, m = step(state, train_loop.make_batch(cfg, i))
        assert bool(jnp.isfinite(m["loss"])), i
        if (i + 1) % 2 == 0:
            state = switch(state)
    # the step traced against post-switch state still runs the prologue
    jaxpr = jax.make_jaxpr(train_loop.make_train_step(cfg))(
        state, train_loop.make_batch(cfg, 5)).jaxpr
    assert jaxpr_tools.count_pallas_calls(
        jaxpr, "_fxp_qmatmul_kernel") == N_DENSE


# ---------------------------------------------------------------------------
# Other model families through the dense kernel path


def _family_cfg(model: ModelConfig, prologue: bool):
    cfg = _tiny_packed_cfg(prologue)
    cfg = dataclasses.replace(cfg, model=model)
    return dataclasses.replace(cfg, train=dataclasses.replace(
        cfg.train, seq_len=32, global_batch=4))


@pytest.mark.parametrize("prologue", [False, True])
def test_hybrid_ssm_family_dense_kernels(prologue):
    """mamba2-style hybrid: the SSM in/out projections ride the kernel
    path; conv_w / dynamics params keep their use-site dequant."""
    m = ModelConfig(name="tiny-hyb", family="hybrid", num_layers=2,
                    d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                    vocab_size=128, layer_pattern=("attn", "mamba"),
                    ssm_state=16, ssm_head_dim=32)
    cfg = _family_cfg(m, prologue)
    state = train_loop.init_state(cfg)
    step = jax.jit(train_loop.make_train_step(cfg))
    state, metrics = step(state, train_loop.make_batch(cfg, 0))
    assert bool(jnp.isfinite(metrics["loss"]))
    jaxpr = jax.make_jaxpr(train_loop.make_train_step(cfg))(
        state, train_loop.make_batch(cfg, 1)).jaxpr
    fwd = "_fxp_qmatmul_kernel" if prologue else "_fxp_matmul_kernel"
    # period = (attn, mamba): wq wk wv wo + mlp(3) + ssm in/out + head = 10
    assert jaxpr_tools.count_pallas_calls(jaxpr, fwd) == 10


def test_moe_family_dense_kernels():
    """MoE: router is excluded (f32), expert einsum weights keep the
    materialized container (dequantized at the einsum), but the shared
    dense layers still take the kernel path."""
    m = ModelConfig(name="tiny-moe", family="moe", num_layers=2,
                    d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                    vocab_size=128, num_experts=4, experts_per_token=2,
                    moe_d_ff=64)
    cfg = _family_cfg(m, True)
    state = train_loop.init_state(cfg)
    step = jax.jit(train_loop.make_train_step(cfg))
    state, metrics = step(state, train_loop.make_batch(cfg, 0))
    assert bool(jnp.isfinite(metrics["loss"]))
    jaxpr = jax.make_jaxpr(train_loop.make_train_step(cfg))(
        state, train_loop.make_batch(cfg, 1)).jaxpr
    # attn wq wk wv wo + head = 5 (FFN is MoE: expert einsums stay XLA)
    assert jaxpr_tools.count_pallas_calls(jaxpr, "_fxp_qmatmul_kernel") == 5


# ---------------------------------------------------------------------------
# Serving shares the path


def test_engine_serves_packed_dense_path():
    from repro.serve import engine as eng
    cfg = _tiny_packed_cfg(prologue=True)
    state = train_loop.init_state(cfg)
    e = eng.Engine(cfg, state["params"], state["adapt"])
    # serving ALWAYS materializes the words once at load, even with
    # dense_prologue on — weights are static, so holding the f32 master
    # to re-draw words per decode step would be pure overhead
    assert fxp.is_packed(e.qparams["head"])
    assert not any(fxp.is_qdense(l) for l in jax.tree_util.tree_leaves(
        e.qparams, is_leaf=fxp.is_qdense))
    toks = jnp.zeros((2, 8), jnp.int32)
    out, logits = e.generate(toks, 4)
    assert out.shape == (2, 4)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # prefill logits match the XLA-dispatch engine: same RTN words, so the
    # residual difference is the bf16 forward chain (flash vs masked
    # attention reduction order) — bf16-chain tolerance as in
    # test_vjp_differential.TOL
    cfg_x = _tiny_packed_cfg(prologue=False, use_pallas=False)
    e2 = eng.Engine(cfg_x, state["params"], state["adapt"])
    l1, _ = e._prefill(e.qparams, toks, None)
    l2, _ = e2._prefill(e2.qparams, toks, None)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=3e-2, atol=3e-2)
    np.testing.assert_array_equal(np.asarray(jnp.argmax(l1, -1)),
                                  np.asarray(jnp.argmax(l2, -1)))


def test_continuous_batcher_dense_kernel_path():
    """The scheduler shares the serving dispatch: its vmapped decode step
    threads use_pallas, so the batcher drains requests through the fxp
    dense kernels (vmapped pallas_call) and produces tokens."""
    from repro.serve.scheduler import ContinuousBatcher
    cfg = _tiny_packed_cfg(prologue=False)
    state = train_loop.init_state(cfg)
    b = ContinuousBatcher(cfg, state["params"], state["adapt"], slots=2,
                          max_context=32)
    b.submit([1, 2, 3], max_new_tokens=4)
    done = b.run_until_drained(max_steps=40)
    assert len(done) == 1 and len(done[0].output) == 4
