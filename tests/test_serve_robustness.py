"""Overload/fault robustness of the continuous batcher: admission control,
deadlines, journal replay, fault injection, and AdaBits-style precision
degradation. Contract under test: every submitted request reaches EXACTLY
ONE typed terminal status — never a hang, never a silent drop — and
precision switches never recompile the decode step."""
import os

import jax
import jax.numpy as jnp
import pytest

from repro.config import load_config
from repro.core import controller
from repro.serve.engine import quantize_serving_levels
from repro.serve.faults import FaultInjector, TransientDecodeError
from repro.serve.journal import RequestJournal
from repro.serve.policy import PrecisionPolicy
from repro.serve.scheduler import (ContinuousBatcher, DrainTimeout, Request,
                                   Status, TERMINAL)
from repro.train import train_loop


@pytest.fixture(scope="module")
def trained():
    cfg = load_config("tiny")
    state, _ = train_loop.train(cfg, steps=3, log=lambda s: None)
    return cfg, state


def _batcher(trained, **kw):
    cfg, state = trained
    kw.setdefault("slots", 2)
    kw.setdefault("max_context", 32)
    return ContinuousBatcher(cfg, state["params"], state["adapt"], **kw)


# ---------------------------------------------------------------------------
# Precision policy (pure unit tests, no model)


def test_policy_pinned_trace():
    """Hand-verified hysteresis trace: patience=2 pressure steps down one
    level at a time, patience=2 drained steps back up, mixed observations
    reset, no level skipping."""
    pol = PrecisionPolicy(levels=(8, 6, 4), high_watermark=4,
                          low_watermark=1, patience=2)
    depths = [0, 5, 5, 5, 5, 2, 0, 0, 0, 0, 5, 0]
    trace = [pol.observe(d) for d in depths]
    assert trace == [8, 8, 6, 6, 4, 4, 4, 6, 6, 8, 8, 8], trace


def test_policy_latency_trigger_and_validation():
    pol = PrecisionPolicy(levels=(8, 4), high_watermark=100,
                          low_watermark=1, p95_high_ms=50.0, patience=1)
    assert pol.observe(0, p95_wait_ms=60.0) == 4      # latency alone degrades
    assert pol.observe(0, p95_wait_ms=0.0) == 8       # and recovers
    with pytest.raises(ValueError):
        PrecisionPolicy(levels=(4, 6, 8))              # not descending
    with pytest.raises(ValueError):
        PrecisionPolicy(levels=())
    with pytest.raises(ValueError):
        PrecisionPolicy(high_watermark=2, low_watermark=2)
    with pytest.raises(ValueError):
        PrecisionPolicy(patience=0)


def test_clamp_adapt_state_wl_fl_arithmetic():
    """AdaBits clamp drops fractional LSBs: WL 8→4 must take 4 bits off FL
    (integer range preserved), and already-lower WLs are untouched."""
    state = {"tensors": {
        "w": {"wl": jnp.int32(8), "fl": jnp.int32(6)},
        "v": {"wl": jnp.int32(3), "fl": jnp.int32(2)},
    }, "strategy": jnp.int32(0)}
    out = controller.clamp_adapt_state(state, 4)
    assert int(out["tensors"]["w"]["wl"]) == 4
    assert int(out["tensors"]["w"]["fl"]) == 2
    assert int(out["tensors"]["v"]["wl"]) == 3
    assert int(out["tensors"]["v"]["fl"]) == 2
    assert int(state["tensors"]["w"]["wl"]) == 8       # input not mutated


# ---------------------------------------------------------------------------
# Admission control + deadlines


def test_overlong_prompt_rejected_not_wrapped(trained):
    """Regression: a prompt >= max_context used to wrap the ring cache
    silently; it must be refused at submit with a typed reason."""
    cb = _batcher(trained, max_context=16)
    req = cb.submit(list(range(16)), max_new_tokens=4)
    assert req.status is Status.REJECTED
    assert req.reason == "prompt_too_long"
    assert req.rid in cb.terminal
    # boundary: max_context - 1 is admissible
    ok = cb.submit(list(range(15)), max_new_tokens=1)
    assert ok.status is Status.PENDING
    done = cb.run_until_drained()
    assert [r.status for r in done] == [Status.OK]


def test_bounded_queue_rejects_overflow(trained):
    cb = _batcher(trained, max_queue=3)
    reqs = [cb.submit([1, 2, 3], max_new_tokens=2) for _ in range(5)]
    statuses = [r.status for r in reqs]
    assert statuses[:3] == [Status.PENDING] * 3
    assert statuses[3:] == [Status.REJECTED] * 2
    assert all(r.reason == "queue_full" for r in reqs[3:])
    done = cb.run_until_drained()
    assert sorted(r.rid for r in done) == [r.rid for r in reqs[:3]]


def test_deadline_expires_queued_requests(trained):
    """Fake clock: queued past-deadline requests become timed_out; an
    admitted request is not expired retroactively."""
    now = [0.0]
    cb = _batcher(trained, slots=1, clock=lambda: now[0])
    fast = cb.submit([1, 2], max_new_tokens=2)             # no deadline
    slow = cb.submit([3, 4], max_new_tokens=2, timeout=5.0)
    cb.step()                                              # fast admitted
    now[0] = 10.0                                          # deadline passes
    done = cb.run_until_drained()
    by = {r.rid: r for r in done}
    assert by[slow.rid].status is Status.TIMED_OUT
    assert by[slow.rid].reason == "deadline_expired"
    assert by[fast.rid].status is Status.OK


def test_default_timeout_from_config(trained):
    now = [100.0]
    cb = _batcher(trained, default_timeout=7.0, clock=lambda: now[0])
    req = cb.submit([1, 2], max_new_tokens=2)
    assert req.deadline == 107.0
    explicit = cb.submit([1, 2], max_new_tokens=2, deadline=200.0)
    assert explicit.deadline == 200.0


# ---------------------------------------------------------------------------
# Drain report


def test_drain_timeout_names_stranded_requests(trained):
    cb = _batcher(trained, slots=1)
    a = cb.submit([1, 2], max_new_tokens=8)
    b = cb.submit([3, 4], max_new_tokens=8)
    with pytest.raises(DrainTimeout) as ei:
        cb.run_until_drained(max_steps=3)
    assert set(ei.value.unfinished) == {a.rid, b.rid}
    assert str(sorted(ei.value.unfinished)) in str(ei.value)
    # the batcher is still consistent: the caller can resume the drain
    done = cb.run_until_drained()
    assert {r.rid for r in done} == {a.rid, b.rid}
    assert all(r.status is Status.OK for r in done)


# ---------------------------------------------------------------------------
# Journal + replica-loss replay


def test_journal_replay_after_replica_loss(trained, tmp_path):
    """Kill a batcher mid-flight; a recovered batcher re-admits exactly
    the unfinished requests (original rids) and finishes them."""
    cfg, state = trained
    jp = str(tmp_path / "journal.jsonl")
    cb = _batcher(trained, slots=1, journal_path=jp)
    reqs = [cb.submit([i + 1, i + 2], max_new_tokens=2) for i in range(4)]
    for _ in range(4):          # finishes request 0, leaves 1–3 in flight
        cb.step()
    finished_before = set(cb.terminal)
    assert finished_before     # at least one completed pre-crash
    del cb                      # replica dies; only the journal survives

    with open(jp, "a") as f:
        f.write('{"ev": "terminal", "rid"')   # torn write at crash time

    cb2 = ContinuousBatcher.recover(cfg, state["params"], state["adapt"],
                                    journal_path=jp, slots=1,
                                    max_context=32)
    replayed = [r.rid for r in cb2.queue]
    assert replayed == [r.rid for r in reqs if r.rid not in finished_before]
    done = cb2.run_until_drained()
    assert {r.rid for r in done} == set(replayed)
    assert all(r.status is Status.OK for r in done)
    # second recovery after a clean drain replays nothing
    cb2.journal.close()
    assert RequestJournal.unfinished(jp) == []


def test_evicted_requests_are_replayable(trained, tmp_path):
    cfg, state = trained
    jp = str(tmp_path / "evict.jsonl")
    cb = _batcher(trained, slots=1, journal_path=jp)
    r0 = cb.submit([1, 2], max_new_tokens=2)
    r1 = cb.submit([3, 4], max_new_tokens=2)
    cb.step()
    evicted = cb.evict_all()
    assert {r.rid for r in evicted} == {r0.rid, r1.rid}
    assert all(r.status is Status.EVICTED for r in evicted)
    assert not cb.queue and all(s.free for s in cb.slots)
    cb.journal.close()
    assert [e["rid"] for e in RequestJournal.unfinished(jp)] == \
        [r0.rid, r1.rid]


# ---------------------------------------------------------------------------
# Fault injection


def test_nan_fault_quarantines_and_retries_to_same_output(trained):
    """A NaN-corrupted slot is quarantined and its request restarted; the
    retried output must equal the fault-free run (state fully reset)."""
    clean = _batcher(trained, slots=1)
    ref = clean.submit([5, 7, 9], max_new_tokens=4)
    clean.run_until_drained()

    fi = FaultInjector(nan_steps={2: (0,)})
    cb = _batcher(trained, slots=1, faults=fi, retry_budget=2)
    req = cb.submit([5, 7, 9], max_new_tokens=4)
    done = cb.run_until_drained()
    assert [r.rid for r in done] == [req.rid]
    assert req.status is Status.OK
    assert req.output == ref.output
    assert cb.stats["retries"] == 1
    assert cb.stats["quarantines"] == 1
    assert fi.fired == [("nan", 2, (0,))]


def test_nan_fault_exhausts_retry_budget(trained):
    """Corrupting every step leaves no clean attempt: the request fails
    with the typed reason after exactly retry_budget re-admissions."""
    fi = FaultInjector(nan_steps={s: (0,) for s in range(50)})
    cb = _batcher(trained, slots=1, faults=fi, retry_budget=2)
    req = cb.submit([1, 2, 3], max_new_tokens=4)
    done = cb.run_until_drained()
    assert req.status is Status.FAILED
    assert req.reason == "non_finite_logits"
    assert cb.stats["retries"] == 2
    assert [r.rid for r in done] == [req.rid]


def test_transient_error_retried_within_step(trained):
    fi = FaultInjector(error_steps={1})
    cb = _batcher(trained, slots=1, faults=fi, transient_retries=2)
    req = cb.submit([1, 2, 3], max_new_tokens=4)
    done = cb.run_until_drained()
    assert req.status is Status.OK
    assert cb.stats["transient_decode_errors"] == 1
    assert cb.stats.get("retries", 0) == 0    # in-step retry, no re-admit
    assert len(done) == 1


def test_persistent_errors_fail_typed_not_hang(trained):
    """Every attempt at every step raises: requests burn their re-admit
    budget and fail typed — run_until_drained terminates, nothing hangs."""
    fi = FaultInjector(error_steps=set(range(100)), persistent_errors=True)
    cb = _batcher(trained, slots=2, faults=fi, retry_budget=1,
                  transient_retries=1)
    reqs = [cb.submit([1, 2], max_new_tokens=2) for _ in range(3)]
    done = cb.run_until_drained(max_steps=200)
    assert {r.rid for r in done} == {r.rid for r in reqs}
    assert all(r.status is Status.FAILED for r in done)


def test_seeded_injector_is_deterministic():
    a = FaultInjector.seeded(7, steps=50, slots=4, nan_rate=0.2,
                             error_rate=0.1)
    b = FaultInjector.seeded(7, steps=50, slots=4, nan_rate=0.2,
                             error_rate=0.1)
    assert a.nan_steps == b.nan_steps
    assert a._error_steps == b._error_steps
    assert a.nan_steps and a._error_steps    # rates actually fire


# ---------------------------------------------------------------------------
# AdaBits-style degradation


def test_degradation_trace_and_zero_recompiles(trained):
    """Under queue pressure WL must walk down the ladder one level at a
    time, recover after the drain, reproduce exactly across runs, and
    never recompile the jitted decode."""
    def run():
        pol = PrecisionPolicy(levels=(8, 6, 4), high_watermark=3,
                              low_watermark=1, patience=2)
        cb = _batcher(trained, slots=1, policy=pol)
        for _ in range(6):
            cb.submit([1, 2, 3], max_new_tokens=6)
        done = cb.run_until_drained()
        return cb, done

    cb, done = run()
    trace = cb.wl_trace
    assert trace[0] == 8 and trace[-1] == 8
    assert min(trace) == 4                     # reached the floor
    ladder = {8: 0, 6: 1, 4: 2}
    for prev, cur in zip(trace, trace[1:]):    # no level skipping
        assert abs(ladder[cur] - ladder[prev]) <= 1, (prev, cur)
    assert all(r.status is Status.OK for r in done)
    assert cb.stats["precision_switches"] >= 2
    # the recompile-freedom claim, asserted directly on the jit cache
    assert cb._decode._cache_size() == 1
    cb2, _ = run()
    assert cb2.wl_trace == trace               # deterministic


def test_quantize_serving_levels_structural_identity(trained):
    cfg, state = trained
    levels = quantize_serving_levels(state["params"], state["adapt"],
                                     cfg.quant, (8, 6, 4))
    assert set(levels) == {8, 6, 4}
    ref = jax.tree_util.tree_structure(levels[8])
    for wl in (6, 4):
        assert jax.tree_util.tree_structure(levels[wl]) == ref
        for a, b in zip(jax.tree_util.tree_leaves(levels[8]),
                        jax.tree_util.tree_leaves(levels[wl])):
            assert a.shape == b.shape and a.dtype == b.dtype
    # degraded levels actually differ numerically from full precision
    diff = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(levels[8]),
        jax.tree_util.tree_leaves(levels[4]))
        if jnp.issubdtype(a.dtype, jnp.floating))
    assert diff > 0.0


def test_quantize_serving_levels_empty_adapt(trained):
    cfg, state = trained
    out = quantize_serving_levels(state["params"], {"tensors": {}},
                                  cfg.quant, (8, 6, 4))
    assert list(out) == [8]    # passthrough under the top level only


# ---------------------------------------------------------------------------
# The whole contract at once


def test_every_submission_reaches_exactly_one_terminal_status(trained):
    """Flood + faults + deadlines + bounded queue, all at once: every
    submitted rid ends in ``terminal`` with a typed status, exactly once,
    and the per-status stats add up to the submission count."""
    fi = FaultInjector.seeded(3, steps=400, slots=2, nan_rate=0.08,
                              error_rate=0.05)
    now = [0.0]

    def clock():
        now[0] += 0.01
        return now[0]

    cb = _batcher(trained, slots=2, max_queue=6, retry_budget=1,
                  faults=fi, clock=clock)
    reqs = []
    for i in range(14):
        timeout = 0.5 if i % 5 == 4 else None   # some tight deadlines
        reqs.append(cb.submit([i + 1, i + 2], max_new_tokens=3,
                              timeout=timeout))
    done = cb.run_until_drained(max_steps=400)
    assert set(cb.terminal) == {r.rid for r in reqs}
    for r in reqs:
        assert r.status in TERMINAL, (r.rid, r.status)
        assert cb.terminal[r.rid] is r
    assert sum(cb.stats[s.value] for s in TERMINAL) == len(reqs)
    assert cb.stats["submitted"] == len(reqs)
    # double-finish is programmatically impossible
    ok = next((r for r in reqs if r.status is Status.OK), None)
    if ok is not None:
        with pytest.raises(AssertionError):
            cb._finish(ok, Status.FAILED, "again")
