"""Tail-masked Pallas grids: prime/odd dims run correct multi-block kernels.

Pallas pads partial boundary blocks with garbage/NaN (interpret mode pads
with NaN; compiled TPU leaves whatever was in VMEM), so before this suite's
machinery existed the wrappers refused non-divisible block boundaries and
fell back to divisor blocks or — for prime-ish dims — one whole-dim block
(a TPU VMEM hazard). Now every gridded kernel masks its own tails, and this
suite pins the contract on the nastiest shapes:

  * prime ⟨M,K,N⟩ / Sq/Skv: forward AND VJP outputs match the XLA
    reference to the existing suite tolerances, zero NaNs anywhere;
  * the chosen block is the requested clamp — min(requested, dim), NEVER
    the whole dim — read off the traced pallas_call block shapes, and the
    grid is the matching multi-block ``pl.cdiv`` (VMEM stays bounded);
  * causal + sliding-window + GQA + softcap compose with the tail mask at
    the boundary blocks (the one shared ``_block_mask``);
  * the old `_fit_block` divisor scan is gone (no O(b) trace-time scan,
    no whole-dim fallback path left to regress into);
  * a prime-seq-len jitted train step still lowers to the Pallas fwd+bwd
    kernels — no silent XLA fallback at awkward dims.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import jaxpr_tools
from repro.config import load_config
from repro.kernels import flash_attention as fa
from repro.kernels import fxp_matmul as fm
from repro.kernels import ops, ref
from repro.train import train_loop

KEY = jax.random.PRNGKey(23)

PRIME_MKN = [(127, 509, 257), (257, 127, 509), (131, 131, 131)]
PRIME_SEQ = [(131, 257), (127, 127), (61, 131)]


def _assert_no_nan(x, msg=""):
    assert not np.isnan(np.asarray(x, np.float32)).any(), f"NaN leak: {msg}"


# ---------------------------------------------------------------------------
# The divisor scan is gone: clamp only, O(1), no whole-dim fallback


def test_fit_block_divisor_scan_is_gone():
    """`_fit_block` (the per-dim O(b) pure-Python divisor scan at trace
    time, with its whole-dim VMEM-hazard fallback) must not survive
    anywhere in the kernel modules."""
    assert not hasattr(fm, "_fit_block")
    assert not hasattr(fa, "_fit_block")


def test_clamp_block_is_plain_min():
    # primes that the old scan would have blown up to the whole dim
    for b, d in [(256, 509), (512, 100003), (64, 127), (128, 128), (7, 3)]:
        assert fm._clamp_block(b, d) == min(b, d)


# ---------------------------------------------------------------------------
# Matmul kernels: prime dims, multi-block grids, fwd parity


@pytest.mark.parametrize("m,k,n", PRIME_MKN)
def test_fxp_matmul_prime_dims_multiblock(m, k, n):
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, m))
    x = jax.random.normal(k1, (m, k), jnp.float32)
    wq = jax.random.randint(k2, (k, n), -128, 128, jnp.int8)
    s = jnp.float32(1 / 64)
    got = fm.fxp_matmul(x, wq, s, bm=64, bn=64, bk=64, interpret=True)
    _assert_no_nan(got, f"fxp_matmul {m}x{k}x{n}")
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.ref_fxp_matmul(x, wq, s)),
                               rtol=1e-5, atol=1e-2)


@pytest.mark.parametrize("m,k,n", PRIME_MKN)
def test_int8_matmul_prime_dims_exact(m, k, n):
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, n))
    xq = jax.random.randint(k1, (m, k), -128, 128, jnp.int8)
    wq = jax.random.randint(k2, (k, n), -128, 128, jnp.int8)
    got = fm.int8_matmul(xq, wq, jnp.float32(0.02), jnp.float32(0.3),
                         bm=64, bn=64, bk=64, interpret=True)
    want = ref.ref_int8_matmul(xq, wq, jnp.float32(0.02), jnp.float32(0.3))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_int8_matmul_rejects_mismatched_k():
    """K mismatch must fail AT THE WRAPPER, not deep inside pallas_call."""
    xq = jnp.zeros((16, 32), jnp.int8)
    wq = jnp.zeros((48, 16), jnp.int8)
    with pytest.raises(AssertionError):
        fm.int8_matmul(xq, wq, jnp.float32(1.0), jnp.float32(1.0),
                       interpret=True)


@pytest.mark.parametrize("m,k,n", PRIME_MKN)
def test_matmul_blocks_are_clamp_never_whole_dim(m, k, n):
    """Structure criterion: with the requested blocks smaller than every
    prime dim, the traced pallas_call must carry exactly the requested
    block shape (VMEM bound) and a multi-block cdiv grid — the whole-dim
    escape hatch is gone."""
    bm = bn = bk = 64
    x = jnp.zeros((m, k), jnp.float32)
    wq = jnp.zeros((k, n), jnp.int8)
    jaxpr = jax.make_jaxpr(lambda a, b: fm.fxp_matmul(
        a, b, jnp.float32(1.0), bm=bm, bn=bn, bk=bk,
        interpret=True))(x, wq).jaxpr
    (grid,) = jaxpr_tools.pallas_grids(jaxpr)
    (blocks,) = jaxpr_tools.pallas_block_shapes(jaxpr)
    assert grid == (-(-m // bm), -(-n // bn), -(-k // bk))
    assert all(g > 1 for g in grid), f"single-block grid {grid}"
    assert (bm, bk) in blocks and (bk, bn) in blocks and (bm, bn) in blocks
    for shape in blocks:
        assert m not in shape and k not in shape and n not in shape, \
            f"whole-dim block leaked into {blocks}"


def test_matmul_grad_blocks_are_clamp_never_whole_dim():
    """Same structure criterion for BOTH backward kernels via jax.grad."""
    m, k, n = 127, 509, 257
    bm = bn = bk = 64
    x = jnp.zeros((m, k), jnp.float32)
    wq = jnp.zeros((k, n), jnp.int8)
    jaxpr = jax.make_jaxpr(jax.grad(lambda a: jnp.sum(fm.fxp_matmul_vjp(
        a, wq, jnp.float32(1.0), bm=bm, bn=bn, bk=bk,
        interpret=True))))(x).jaxpr
    names = jaxpr_tools.pallas_kernel_names(jaxpr)
    assert any("_matmul_dx_kernel" in s for s in names)
    assert any("_matmul_dw_kernel" in s for s in names)
    for grid, blocks in zip(jaxpr_tools.pallas_grids(jaxpr),
                            jaxpr_tools.pallas_block_shapes(jaxpr)):
        assert all(g > 1 for g in grid), f"single-block grid {grid}"
        for shape in blocks:
            assert all(s <= 64 for s in shape), \
                f"block exceeded the requested clamp: {blocks}"


# ---------------------------------------------------------------------------
# Matmul VJPs: prime dims grad parity


@pytest.mark.parametrize("m,k,n", PRIME_MKN)
def test_fxp_matmul_grad_parity_prime_dims(m, k, n):
    k1, k2, k3 = jax.random.split(jax.random.fold_in(KEY, m * 3 + n), 3)
    x = jax.random.normal(k1, (m, k), jnp.float32)
    wq = jax.random.randint(k2, (k, n), -128, 128, jnp.int8)
    s = jnp.float32(1 / 32)
    cot = jax.random.normal(k3, (m, n), jnp.float32)
    gp = jax.grad(lambda x, s: jnp.sum(
        fm.fxp_matmul_vjp(x, wq, s, bm=64, bn=64, bk=64,
                          interpret=True) * cot), (0, 1))(x, s)
    gr = jax.grad(lambda x, s: jnp.sum(
        ref.ref_fxp_matmul(x, wq, s) * cot), (0, 1))(x, s)
    for got, want, name in zip(gp, gr, ("dx", "dscale")):
        _assert_no_nan(got, f"{name} {m}x{k}x{n}")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4, err_msg=name)


def test_int8_matmul_grad_parity_prime_dims():
    m, k, n = 127, 257, 131
    k1, k2, k3 = jax.random.split(KEY, 3)
    xq = jax.random.randint(k1, (m, k), -128, 128, jnp.int8)
    wq = jax.random.randint(k2, (k, n), -128, 128, jnp.int8)
    cot = jax.random.normal(k3, (m, n), jnp.float32)
    sx, sw = jnp.float32(0.02), jnp.float32(0.3)
    gp = jax.grad(lambda a, b: jnp.sum(
        fm.int8_matmul_vjp(xq, wq, a, b, bm=64, bn=64, bk=64,
                           interpret=True) * cot), (0, 1))(sx, sw)
    gr = jax.grad(lambda a, b: jnp.sum(
        ref.ref_int8_matmul(xq, wq, a, b) * cot), (0, 1))(sx, sw)
    np.testing.assert_allclose(np.asarray(gp[0]), np.asarray(gr[0]),
                               rtol=2e-4, atol=2e-4, err_msg="dsx")
    np.testing.assert_allclose(np.asarray(gp[1]), np.asarray(gr[1]),
                               rtol=2e-4, atol=2e-4, err_msg="dsw")


# ---------------------------------------------------------------------------
# Flash attention: prime Sq/Skv under causal + window + GQA + softcap


ATTN_TAIL_CASES = [
    dict(causal=True),
    dict(causal=False),
    dict(causal=True, window=37),
    dict(causal=True, window=50, softcap=15.0),
]


@pytest.mark.parametrize("kw", ATTN_TAIL_CASES,
                         ids=[str(c) for c in ATTN_TAIL_CASES])
@pytest.mark.parametrize("sq,skv", PRIME_SEQ)
def test_attention_prime_dims_fwd_parity(sq, skv, kw):
    """Prime Sq/Skv with 32-blocks: every grid has tail blocks in BOTH
    sequence dims; causal/window/GQA/softcap compose with the tail mask."""
    k1, k2, k3 = jax.random.split(jax.random.fold_in(KEY, sq * skv), 3)
    q = jax.random.normal(k1, (2, sq, 4, 32), jnp.float32)
    k = jax.random.normal(k2, (2, skv, 2, 32), jnp.float32)
    v = jax.random.normal(k3, (2, skv, 2, 32), jnp.float32)
    got = ops.attention(q, k, v, use_pallas=True, bq=32, bk=32, **kw)
    _assert_no_nan(got, f"attention fwd {sq}/{skv} {kw}")
    want = ref.ref_attention(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("kw", ATTN_TAIL_CASES,
                         ids=[str(c) for c in ATTN_TAIL_CASES])
@pytest.mark.parametrize("sq,skv", PRIME_SEQ)
def test_attention_prime_dims_grad_parity(sq, skv, kw):
    k1, k2, k3, k4 = jax.random.split(jax.random.fold_in(KEY, sq + skv), 4)
    q = jax.random.normal(k1, (1, sq, 4, 32), jnp.float32)
    k = jax.random.normal(k2, (1, skv, 2, 32), jnp.float32)
    v = jax.random.normal(k3, (1, skv, 2, 32), jnp.float32)
    cot = jax.random.normal(k4, q.shape, jnp.float32)
    gp = jax.grad(lambda q, k, v: jnp.sum(
        ops.attention(q, k, v, use_pallas=True, bq=32, bk=32, **kw) * cot),
        (0, 1, 2))(q, k, v)
    gr = ref.ref_attention_grads(q, k, v, cot, **kw)
    for got, want, name in zip(gp, gr, "qkv"):
        _assert_no_nan(got, f"d{name} {sq}/{skv} {kw}")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3,
                                   err_msg=f"d{name} {sq}/{skv} {kw}")


def test_attention_prime_dims_dead_rows():
    """Sq > Skv (both prime) under causal end-alignment: the dead-row
    convention (exact-0 rows, lse = NEG_INF) must survive tail masking."""
    k1, k2, k3 = jax.random.split(KEY, 3)
    sq, skv = 131, 61
    q = jax.random.normal(k1, (1, sq, 2, 16), jnp.float32)
    k = jax.random.normal(k2, (1, skv, 2, 16), jnp.float32)
    v = jax.random.normal(k3, (1, skv, 2, 16), jnp.float32)
    out = ops.attention(q, k, v, use_pallas=True, bq=32, bk=32)
    _assert_no_nan(out, "dead-row fwd")
    np.testing.assert_array_equal(np.asarray(out[:, :sq - skv]), 0.0)


def test_attention_blocks_are_clamp_never_whole_dim():
    """Block/grid structure for all three attention kernels at prime
    Sq/Skv: q/k blocks equal the requested 32-clamp, grids stay
    multi-block in both sequence dims."""
    sq, skv = 131, 257
    q = jnp.zeros((1, sq, 4, 32), jnp.float32)
    k = jnp.zeros((1, skv, 2, 32), jnp.float32)
    jaxpr = jax.make_jaxpr(jax.grad(lambda q, k, v: jnp.sum(
        ops.attention(q, k, v, use_pallas=True, bq=32, bk=32)),
        (0, 1, 2)))(q, k, k).jaxpr
    names = jaxpr_tools.pallas_kernel_names(jaxpr)
    assert {"_flash_kernel", "_flash_dq_kernel",
            "_flash_dkv_kernel"} <= {n for n in names}
    for name, grid in zip(names, jaxpr_tools.pallas_grids(jaxpr)):
        nq, nk = -(-sq // 32), -(-skv // 32)
        # _flash_dkv folds the GQA group into its innermost dim: rep·nq
        assert nk in grid and (nq in grid or 2 * nq in grid), (name, grid)
        assert sq not in grid and skv not in grid, \
            f"{name}: whole-dim block leaked, grid={grid}"
    for name, blocks in zip(names, jaxpr_tools.pallas_block_shapes(jaxpr)):
        for shape in blocks:
            assert sq not in shape and skv not in shape, \
                f"{name}: whole-dim block {shape}"


# ---------------------------------------------------------------------------
# ops-level default blocks on prime dims (the controller's entry points)


def test_ops_fxp_matmul_prime_dims_default_blocks():
    """The op-level wrapper (default 256/256/512 blocks) on prime dims:
    blocks clamp to min(default, dim) — multi-block where the dim exceeds
    the default, exact parity either way."""
    k1, k2 = jax.random.split(KEY)
    m, k, n = 509, 1031, 127        # M and K exceed the default blocks
    x = jax.random.normal(k1, (m, k), jnp.float32)
    wq = jax.random.randint(k2, (k, n), -128, 128, jnp.int8)
    s = jnp.float32(1 / 64)
    got = ops.fxp_matmul(x, wq, s, use_pallas=True)
    _assert_no_nan(got, "ops.fxp_matmul prime")
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.ref_fxp_matmul(x, wq, s)),
                               rtol=1e-5, atol=5e-2)
    jaxpr = jax.make_jaxpr(lambda a: ops.fxp_matmul(
        a, wq, s, use_pallas=True))(x).jaxpr
    (grid,) = jaxpr_tools.pallas_grids(jaxpr)
    assert grid == (-(-m // 256), 1, -(-k // 512))


# ---------------------------------------------------------------------------
# CI acceptance: a prime-seq-len jitted train step still lowers to Pallas


def test_prime_seq_train_step_keeps_pallas_kernels():
    """No silent XLA fallback at awkward dims: with quant.use_pallas=True
    and a PRIME seq_len, the jitted differentiated train step still
    contains the flash forward AND both backward kernels."""
    cfg = load_config("tiny")
    cfg = dataclasses.replace(
        cfg,
        quant=dataclasses.replace(cfg.quant, use_pallas=True,
                                  stochastic_rounding=False),
        train=dataclasses.replace(cfg.train, seq_len=61, adapt_interval=1000,
                                  log_every=1))
    state = train_loop.init_state(cfg)
    batch = train_loop.make_batch(cfg, 0)
    jaxpr = jax.make_jaxpr(train_loop.make_train_step(cfg))(
        state, batch).jaxpr
    for kern in ("_flash_kernel", "_flash_dq_kernel", "_flash_dkv_kernel"):
        assert jaxpr_tools.count_pallas_calls(jaxpr, kern) == 1, kern


def test_prime_seq_train_step_runs_nan_free():
    """One real optimizer step at prime seq_len: finite loss and grads."""
    cfg = load_config("tiny")
    cfg = dataclasses.replace(
        cfg,
        quant=dataclasses.replace(cfg.quant, use_pallas=True,
                                  stochastic_rounding=False),
        train=dataclasses.replace(cfg.train, seq_len=61, adapt_interval=1000,
                                  log_every=1))
    state = train_loop.init_state(cfg)
    step = jax.jit(train_loop.make_train_step(cfg))
    state, metrics = step(state, train_loop.make_batch(cfg, 0))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
