"""MuPPET baseline (paper §2.2) invariants."""
import jax
import jax.numpy as jnp

from repro.core import muppet


def test_block_fp_on_grid_and_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (512,)) * 2.0
    for wl in (8, 12, 14, 16):
        q = muppet.quantize_block_fp(x, wl)
        s = muppet.block_fp_scale(x, wl)
        scaled = q * jnp.exp2(s)
        assert float(jnp.max(jnp.abs(scaled - jnp.round(scaled)))) < 1e-3
        assert float(jnp.max(scaled)) <= 2.0 ** (wl - 1) - 1 + 1e-3
        assert float(jnp.min(scaled)) >= -(2.0 ** (wl - 1)) - 1e-3


def test_block_fp_error_shrinks_with_wl():
    x = jax.random.normal(jax.random.PRNGKey(1), (4096,))
    errs = [float(jnp.mean(jnp.abs(muppet.quantize_block_fp(x, wl) - x)))
            for wl in (8, 12, 14, 16)]
    assert all(a >= b for a, b in zip(errs, errs[1:])), errs


def test_wl32_is_identity():
    x = jax.random.normal(jax.random.PRNGKey(2), (64,))
    assert float(jnp.max(jnp.abs(muppet.quantize_block_fp(x, 32) - x))) == 0


def test_precision_only_increases():
    st = muppet.init_state(num_layers=4, threshold=1.05, violations_needed=2)
    levels = [int(st["level"])]
    # falling diversity → p = max/now grows → violations accumulate → switch
    for div in (10.0, 8.0, 6.0, 5.0, 4.0, 3.5, 3.0, 2.5):
        st = muppet.end_of_epoch(st, jnp.float32(div))
        levels.append(int(st["level"]))
    assert all(b >= a for a, b in zip(levels, levels[1:]))
    assert levels[-1] > 0, "switch should have triggered"
    assert int(muppet.current_wl(st)) in muppet.LADDER


def test_quantize_params_respects_level():
    params = {"w": jnp.ones((8, 8)) * 0.37, "b": jnp.ones((8,))}
    st = muppet.init_state(1)
    q = muppet.quantize_params(params, st)
    assert q["w"].dtype == jnp.float32
    # vectors pass through untouched
    assert float(jnp.max(jnp.abs(q["b"] - 1.0))) == 0.0
    # at the top level (float32) weights pass through too
    st["level"] = jnp.int32(len(muppet.LADDER) - 1)
    q32 = muppet.quantize_params(params, st)
    assert float(jnp.max(jnp.abs(q32["w"] - params["w"]))) == 0.0
