"""Multi-device shard_map quantize tests (4 forced host CPU devices).

The real assertions need ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
set BEFORE the first jax import — the dedicated CI matrix entry does that.
In a single-device session those tests skip and a subprocess shim re-runs
this module with the flag set, so the local full-suite keeps coverage.

Covered: (a) the shard_map-wrapped fused quantize matches the unsharded
pure-jnp oracle (``ref_sr_quantize_fused_sharded_words``) bit-exactly for
FSDP / TP / 2-D / composed-axis / stacked layouts; (b) no param-sized
all-gather appears in the quantize jaxpr or its compiled HLO — the f32
master never crosses the mesh; (c) unevenly-sharded leaves fall back to
the XLA noise+constraint path instead of crashing.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import jaxpr_tools, sharding as shd
from repro.config import QuantConfig
from repro.core import controller
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(3)
N_DEV = jax.device_count()

multi = pytest.mark.skipif(
    N_DEV < 4, reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4")


def _mesh22():
    return Mesh(np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))


def _grid(shape, spec, mesh):
    g = shd.shard_grid(shape, spec, mesh)
    assert g is not None
    return g


def _eq(got, want, msg=""):
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                  err_msg=msg)


# ---------------------------------------------------------------------------
# (a) bit-exact parity with the single-device oracle


@multi
@pytest.mark.parametrize("spec,shape", [
    (P("data", None), (8, 640)),               # FSDP row shard
    (P(None, "model"), (48, 256)),             # TP col shard
    (P("data", "model"), (16, 512)),           # 2-D
    (P(("data", "model"), None), (16, 384)),   # composed axes on one dim
    (P(None, None), (24, 96)),                 # replicated (degenerate)
])
def test_sharded_matches_oracle_bitexact(spec, shape):
    mesh = _mesh22()
    x = jax.random.normal(KEY, shape) * 2
    sh = NamedSharding(mesh, spec)
    xs = jax.device_put(x, sh)
    got = ops.sr_quantize_fused(xs, 13, 8, 4, use_pallas=True, sharding=sh)
    if all(a is None for a in spec):
        want = ref.ref_sr_quantize_fused_words(x, 13, 8, 4)
    else:
        want = ref.ref_sr_quantize_fused_sharded_words(
            x, 13, 8, 4, _grid(shape, spec, mesh))
    _eq(got, want, f"{spec} {shape}")


@multi
@pytest.mark.parametrize("spec", [
    P("data", None, None),          # layers sharded (stacked FSDP)
    P(None, None, "model"),         # within-layer TP
    P("data", None, "model"),       # both
])
def test_sharded_stacked_heterogeneous_bitexact(spec):
    mesh = _mesh22()
    x = jax.random.normal(KEY, (4, 24, 256)) * 2
    wl = jnp.asarray([3, 8, 12, 16], jnp.int32)
    fl = jnp.asarray([1, 4, 8, 10], jnp.int32)
    sh = NamedSharding(mesh, spec)
    xs = jax.device_put(x, sh)
    got = ops.sr_quantize_fused(xs, 17, wl, fl, use_pallas=True, sharding=sh)
    want = ref.ref_sr_quantize_fused_sharded_words(
        x, 17, wl, fl, _grid(x.shape, spec, mesh))
    _eq(got, want, str(spec))


@multi
@pytest.mark.parametrize("stacked", [False, True])
def test_sharded_int8_bitexact(stacked):
    mesh = _mesh22()
    if stacked:
        x = jax.random.normal(KEY, (2, 16, 256)) * 3
        fl = jnp.asarray([4, 6], jnp.int32)
        spec = P("data", None, "model")
    else:
        x = jax.random.normal(KEY, (16, 512)) * 3
        fl = jnp.int32(5)
        spec = P("data", "model")
    sh = NamedSharding(mesh, spec)
    xs = jax.device_put(x, sh)
    got = ops.sr_quantize_fused_int8(xs, 19, fl, use_pallas=True, sharding=sh)
    want = ref.ref_sr_quantize_fused_sharded_words(
        x, 19, None, fl, _grid(x.shape, spec, mesh), int8=True)
    _eq(got, want)


@multi
def test_quantize_params_sharded_end_to_end():
    """controller.quantize_params with a sharding tree: every leaf regime
    (dense FSDP, stacked TP) lands on the fused path, words match the
    oracles, and the outputs come back laid out on the mesh."""
    mesh = _mesh22()
    qcfg = dataclasses.replace(QuantConfig(), use_pallas=True)
    params = {"dense": {"w": jax.random.normal(KEY, (32, 64))},
              "blocks": {"mlp": {"w": jax.random.normal(KEY, (4, 16, 64))}}}
    st = controller.init_adapt_state(params, qcfg)
    st["tensors"]["blocks/mlp/w"]["wl"] = jnp.asarray([4, 8, 12, 16],
                                                      jnp.int32)
    st["tensors"]["blocks/mlp/w"]["fl"] = jnp.asarray([2, 4, 8, 10],
                                                      jnp.int32)
    shardings = {"dense": {"w": NamedSharding(mesh, P("data", None))},
                 "blocks": {"mlp": {"w": NamedSharding(
                     mesh, P(None, None, "model"))}}}
    params = jax.tree.map(jax.device_put, params, shardings)
    q = controller.quantize_params(params, st, qcfg, key=KEY,
                                   shardings=shardings)

    td = st["tensors"]["dense/w"]
    _eq(q["dense"]["w"],
        ref.ref_sr_quantize_fused_sharded_words(
            params["dense"]["w"], controller._leaf_seed(KEY, "dense/w"),
            td["wl"], td["fl"], (2, 1)))
    ts = st["tensors"]["blocks/mlp/w"]
    _eq(q["blocks"]["mlp"]["w"],
        ref.ref_sr_quantize_fused_sharded_words(
            params["blocks"]["mlp"]["w"],
            controller._leaf_seed(KEY, "blocks/mlp/w"),
            ts["wl"], ts["fl"], (1, 1, 2)))
    assert q["dense"]["w"].sharding.is_equivalent_to(
        shardings["dense"]["w"], 2)


# ---------------------------------------------------------------------------
# (b) no f32 all-gather anywhere in the quantize program


@multi
def test_no_param_sized_collectives_in_jaxpr_or_hlo():
    mesh = _mesh22()
    qcfg = dataclasses.replace(QuantConfig(), use_pallas=True)
    params = {"dense": {"w": jax.random.normal(KEY, (32, 64))},
              "blocks": {"mlp": {"w": jax.random.normal(KEY, (4, 16, 64))}}}
    st = controller.init_adapt_state(params, qcfg)
    shardings = {"dense": {"w": NamedSharding(mesh, P("data", "model"))},
                 "blocks": {"mlp": {"w": NamedSharding(
                     mesh, P("data", None, "model"))}}}
    params = jax.tree.map(jax.device_put, params, shardings)

    fn = lambda p, k: controller.quantize_params(p, st, qcfg, key=k,
                                                 shardings=shardings)
    min_param = min(leaf.size for leaf in jax.tree.leaves(params))
    jaxpr = jax.make_jaxpr(fn)(params, KEY).jaxpr
    offenders = jaxpr_tools.collective_eqns_of_size(jaxpr, min_param)
    assert not offenders, [str(e) for e in offenders]
    # and after GSPMD partitioning: the compiled module must not reassemble
    # anything — the quantize of a sharded tree is collective-free.
    hlo = jax.jit(fn).lower(params, KEY).compile().as_text()
    assert "all-gather" not in hlo and "all-to-all" not in hlo


# ---------------------------------------------------------------------------
# (c) uneven leaves fall back to the XLA path instead of crashing


@multi
def test_uneven_sharded_leaf_falls_back(monkeypatch):
    """7 rows over a 2-way axis: shard_map needs equal blocks, so the gate
    must refuse and the leaf must keep the XLA noise+constraint path (the
    constraint itself only compiles under jit with uneven shapes — also
    true before the fused path existed — so assert at trace level)."""
    mesh = _mesh22()
    qcfg = dataclasses.replace(QuantConfig(), use_pallas=True)
    params = {"dense": {"w": jax.random.normal(KEY, (7, 64))}}  # 7 % 2 != 0
    st = controller.init_adapt_state(params, qcfg)
    sh = {"dense": {"w": NamedSharding(mesh, P("data", None))}}
    assert not controller._use_fused_prng(
        qcfg, KEY, st["tensors"]["dense/w"]["wl"], params["dense"]["w"],
        sh["dense"]["w"])
    calls = []
    monkeypatch.setattr(ops, "sr_quantize_fused",
                        lambda *a, **k: calls.append(1))
    monkeypatch.setattr(ops, "sr_quantize_fused_int8",
                        lambda *a, **k: calls.append(1))
    out = jax.eval_shape(
        lambda p, k: controller.quantize_params(p, st, qcfg, key=k,
                                                shardings=sh), params, KEY)
    assert not calls and out["dense"]["w"].shape == (7, 64)


# ---------------------------------------------------------------------------
# Single-device shim: keep multi-device coverage in plain full-suite runs


@pytest.mark.skipif(
    N_DEV >= 4 or os.environ.get("GITHUB_ACTIONS") == "true",
    reason="already running multi-device, or CI (the dedicated "
           "multidevice-4 matrix entry covers this — don't run it twice)")
def test_multidevice_suite_in_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         os.path.abspath(__file__)],
        env=env, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-4000:]
