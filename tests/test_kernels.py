"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles.

Every Pallas kernel runs in interpret mode (CPU container); the oracle in
repro.kernels.ref is ground truth.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(42)


# ---------------------------------------------------------------------------
# sr_quantize


@pytest.mark.parametrize("shape", [(7,), (128,), (33, 65), (4, 3, 50), (256, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("wl,fl", [(8, 4), (4, 2), (16, 8), (2, 0)])
def test_sr_quantize_matches_ref(shape, dtype, wl, fl):
    k1, k2 = jax.random.split(KEY)
    x = (jax.random.normal(k1, shape, jnp.float32) * 3).astype(dtype)
    u = jax.random.uniform(k2, shape, jnp.float32)
    got = ops.sr_quantize(x, u, wl, fl, use_pallas=True)
    want = ref.ref_sr_quantize(x, u, wl, fl)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sr_quantize_on_grid():
    """Output values land exactly on the ⟨WL,FL⟩ grid and inside its range."""
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (4096,)) * 10
    u = jax.random.uniform(k2, x.shape)
    q = ops.sr_quantize(x, u, 8, 4, use_pallas=True)
    scaled = np.asarray(q) * 16
    np.testing.assert_array_equal(scaled, np.round(scaled))
    assert scaled.min() >= -128 and scaled.max() <= 127


# ---------------------------------------------------------------------------
# fxp_matmul / int8_matmul


@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (64, 128, 96), (100, 70, 50),
                                   (256, 512, 256),
                                   # primes past the default blocks: partial
                                   # boundary blocks on M and K, tail-masked
                                   (509, 1031, 127)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fxp_matmul_matches_ref(m, k, n, dtype):
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (m, k), jnp.float32).astype(dtype)
    wq = jax.random.randint(k2, (k, n), -128, 128, jnp.int8)
    s = jnp.float32(1 / 64)
    got = ops.fxp_matmul(x, wq, s, use_pallas=True)
    want = ref.ref_fxp_matmul(x, wq, s)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-2)


@pytest.mark.parametrize("m,k,n", [(16, 32, 16), (128, 256, 128), (48, 72, 36),
                                   (509, 1031, 127)])
def test_int8_matmul_matches_ref(m, k, n):
    k1, k2 = jax.random.split(KEY)
    xq = jax.random.randint(k1, (m, k), -128, 128, jnp.int8)
    wq = jax.random.randint(k2, (k, n), -128, 128, jnp.int8)
    got = ops.int8_matmul(xq, wq, jnp.float32(0.02), jnp.float32(0.3),
                          use_pallas=True)
    want = ref.ref_int8_matmul(xq, wq, jnp.float32(0.02), jnp.float32(0.3))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_int8_matmul_exact_integer_accumulation():
    """int32 accumulation must be exact (no float rounding of products)."""
    xq = jnp.full((8, 1024), 127, jnp.int8)
    wq = jnp.full((1024, 8), 127, jnp.int8)
    got = ops.int8_matmul(xq, wq, jnp.float32(1.0), jnp.float32(1.0),
                          use_pallas=True)
    assert float(got[0, 0]) == 127 * 127 * 1024


# ---------------------------------------------------------------------------
# kl_hist


@pytest.mark.parametrize("n", [100, 4096, 70000])
@pytest.mark.parametrize("bins", [50, 150, 256])
def test_kl_hist_matches_ref(n, bins):
    k1, _ = jax.random.split(KEY)
    w = jax.random.normal(k1, (n,))
    q = jnp.round(w * 8) / 8
    got = ops.kl_hist(w, q, bins, use_pallas=True)
    want = ref.ref_kl_hist(w, q, bins)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)
    assert abs(float(got[0].sum()) - n) < 1e-3
    assert abs(float(got[1].sum()) - n) < 1e-3


# ---------------------------------------------------------------------------
# flash attention


@pytest.mark.parametrize("sq,skv", [(128, 128), (64, 128), (1, 128), (96, 96),
                                    # prime seq dims: partial boundary
                                    # blocks in both grid dims (bq=bk=32)
                                    (127, 127), (131, 257)])
@pytest.mark.parametrize("h,hkv", [(4, 4), (8, 2)])
def test_flash_attention_matches_ref(sq, skv, h, hkv):
    k1, k2, k3 = jax.random.split(KEY, 3)
    d = 64
    q = jax.random.normal(k1, (2, sq, h, d), jnp.float32)
    k = jax.random.normal(k2, (2, skv, hkv, d), jnp.float32)
    v = jax.random.normal(k3, (2, skv, hkv, d), jnp.float32)
    got = ops.attention(q, k, v, causal=True, use_pallas=True, bq=32, bk=32)
    want = ref.ref_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=2e-3)


@pytest.mark.parametrize("window", [16, 64])
@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_flash_attention_window_softcap(window, softcap):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (1, 128, 4, 64), jnp.float32)
    k = jax.random.normal(k2, (1, 128, 4, 64), jnp.float32)
    v = jax.random.normal(k3, (1, 128, 4, 64), jnp.float32)
    got = ops.attention(q, k, v, causal=True, window=window, softcap=softcap,
                        use_pallas=True, bq=32, bk=32)
    want = ref.ref_attention(q, k, v, causal=True, window=window,
                             softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=2e-3)


def test_flash_attention_bf16():
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (1, 64, 2, 128), jnp.bfloat16)
    k = jax.random.normal(k2, (1, 64, 2, 128), jnp.bfloat16)
    v = jax.random.normal(k3, (1, 64, 2, 128), jnp.bfloat16)
    got = ops.attention(q, k, v, causal=True, use_pallas=True, bq=32, bk=32)
    want = ref.ref_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=3e-2,
                               atol=3e-2)
