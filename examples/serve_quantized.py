"""Serve an AdaPT-trained model: train briefly, quantize once at the final
per-layer <WL, FL>, and run batched generation — the paper's table-6 story
(the trained network *stays* quantized; no float32 refinement phase).

    PYTHONPATH=src python examples/serve_quantized.py --arch gemma2-2b
"""
import argparse
import dataclasses
import time

import jax.numpy as jnp

from repro.config import apply_overrides, with_shape
from repro.configs import get_smoke_config
from repro.core.controller import snapshot
from repro.serve.engine import Engine
from repro.train import train_loop


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="gemma2-2b",
                    help="any assigned arch id (reduced config is used)")
    ap.add_argument("--train-steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    cfg = dataclasses.replace(
        cfg, train=dataclasses.replace(cfg.train, seq_len=64, global_batch=8,
                                       adapt_interval=10, log_every=10))

    print(f"[1/3] AdaPT-training {cfg.model.name} "
          f"for {args.train_steps} steps...")
    state, _ = train_loop.train(cfg, steps=args.train_steps)

    snap = snapshot(state["adapt"])
    avg_wl = sum(float(t["wl"].mean()) for t in snap.values()) / len(snap)
    print(f"[2/3] final avg word length {avg_wl:.1f} bits "
          f"(vs 32-bit float32) — model ships quantized")

    engine = Engine(cfg, state["params"], state["adapt"])
    prompts = jnp.ones((args.batch, args.prompt_len), jnp.int32)
    t0 = time.perf_counter()
    out, _ = engine.generate(prompts, args.max_new)
    dt = time.perf_counter() - t0
    print(f"[3/3] generated {args.batch}×{args.max_new} tokens "
          f"in {dt:.2f}s (incl. compile)")
    print("      sample:", [int(t) for t in out[0][:16]])


if __name__ == "__main__":
    main()
