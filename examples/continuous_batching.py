"""Continuous batching over an AdaPT-quantized model: requests with
different prompt lengths and budgets share a fixed slot pool; slots recycle
as sequences finish (Orca/vLLM-style scheduling with a static batch — the
jitted decode step never recompiles).

    PYTHONPATH=src python examples/continuous_batching.py
"""
import time

from repro.config import load_config
from repro.serve.scheduler import ContinuousBatcher
from repro.train import train_loop


def main():
    cfg = load_config("tiny")
    print("[1/3] training a tiny AdaPT model (20 steps)...")
    state, _ = train_loop.train(cfg, steps=20, log=lambda s: None)

    cb = ContinuousBatcher(cfg, state["params"], state["adapt"],
                           slots=3, max_context=48)
    print("[2/3] submitting 7 requests with mixed prompts/budgets "
          "into 3 slots...")
    rids = []
    for i in range(7):
        prompt = [(7 * i + j) % cfg.model.vocab_size for j in range(3 + i)]
        rids.append(cb.submit(prompt, max_new_tokens=4 + (i % 3)))

    t0 = time.perf_counter()
    steps = 0
    done = []
    while len(done) < len(rids) and steps < 500:
        done += cb.step()
        steps += 1
        if steps % 5 == 0:
            print(f"    step {steps:3d}: {len(done)}/{len(rids)} finished, "
                  f"slot utilization {cb.utilization:.0%}")
    dt = time.perf_counter() - t0

    print(f"[3/3] drained in {steps} scheduler steps ({dt:.2f}s)")
    for r in sorted(done, key=lambda r: r.rid):
        print(f"    req {r.rid}: prompt {len(r.prompt):2d} tok -> "
              f"generated {r.output}")


if __name__ == "__main__":
    main()
