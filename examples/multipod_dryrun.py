"""Lower + compile one (arch × shape) cell on the 2-pod 512-chip mesh and
print its memory/cost/roofline report — the multi-pod dry-run, example-sized.

    PYTHONPATH=src python examples/multipod_dryrun.py --arch granite-8b \
        --shape train_4k
"""
# The placeholder-device flag must precede every other jax-touching import.
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--single-pod", action="store_true")
    args = ap.parse_args()

    from repro.launch.dryrun import lower_cell
    from repro.roofline import analysis

    rec = lower_cell(args.arch, args.shape,
                     multi_pod=not args.single_pod, do_compile=True)
    print(f"\n{args.arch} × {args.shape} on "
          f"{'1-pod/256' if args.single_pod else '2-pod/512'} chips: "
          f"{rec['status']}")
    if rec["status"] != "compiled":
        print("  reason:", rec.get("reason", rec.get("error")))
        return
    mem = rec.get("memory", {})
    print(f"  compile time : {rec['compile_s']}s")
    print(f"  arg bytes    : {mem.get('argument_bytes', 0) / 2**30:.2f} GiB")
    print(f"  temp bytes   : {mem.get('temp_bytes', 0) / 2**30:.2f} GiB")
    t = analysis.roofline_terms(rec)
    print(f"  roofline     : compute {t['compute_s'] * 1e3:.1f} ms | "
          f"memory {t['memory_s'] * 1e3:.1f} ms | "
          f"collective {t['collective_s'] * 1e3:.1f} ms")
    print(f"  bottleneck   : {t['bottleneck'].replace('_s', '')}")
    coll = rec.get("collectives", {})
    print("  collectives  :",
          {k: f"{v / 2**30:.2f} GiB" for k, v in coll.items() if v})


if __name__ == "__main__":
    main()
