"""Quickstart: AdaPT-quantized training of a tiny LM in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

from repro.config import load_config
from repro.core.controller import snapshot
from repro.train import train_loop


def main():
    # 1. Pick an architecture config (any of the 10 assigned archs works —
    #    `tiny` keeps the quickstart CPU-friendly) and a quantization mode.
    cfg = load_config("tiny", overrides=["quant.mode=simulate",
                                         "train.steps=60"])
    cfg = dataclasses.replace(
        cfg, train=dataclasses.replace(cfg.train, adapt_interval=10,
                                       log_every=10))

    # 2. Train. The loop quantizes the forward pass at each tensor's current
    #    <WL, FL>, runs PushDown/PushUp precision switches every
    #    `adapt_interval` steps, and keeps the float32 master for updates.
    state, history = train_loop.train(cfg)

    # 3. Inspect the controller's final per-layer precisions.
    print("\nper-tensor <WL, FL> after training:")
    for path, t in sorted(snapshot(state["adapt"]).items()):
        print(f"  {path:32s} WL={t['wl']} FL={t['fl']} "
              f"nonzero={float(t['sp'].mean()):.2f}")

    # 4. The quantized model is serving-ready (no f32 refinement phase).
    from repro.serve.engine import Engine
    import jax.numpy as jnp
    engine = Engine(cfg, state["params"], state["adapt"])
    tokens, _ = engine.generate(jnp.zeros((1, 8), jnp.int32), 8)
    print("\ngenerated token ids:", [int(t) for t in tokens[0]])


if __name__ == "__main__":
    main()
