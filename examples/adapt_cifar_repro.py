"""End-to-end paper reproduction driver: AdaPT vs float32 on AlexNet /
ResNet20 (the paper's own models), a few hundred steps, with the per-layer
word-length trajectory dumped as CSV (the data behind the paper's figs 3/4).

    PYTHONPATH=src python examples/adapt_cifar_repro.py \
        --arch resnet20 --classes 100 --steps 300
"""
import argparse
import csv
import os

from benchmarks import paper_tables


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="resnet20",
                    choices=["alexnet", "resnet20"])
    ap.add_argument("--classes", type=int, default=100, choices=[10, 100])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--out", default="experiments/paper")
    args = ap.parse_args()

    cell = paper_tables.run_cifar_experiment(
        args.arch, args.classes, steps=args.steps, batch=args.batch)

    print(f"\n{args.arch} × CIFAR{args.classes} ({args.steps} steps)")
    print(f"  float32 accuracy : {cell['acc_float32']:.3f}")
    print(f"  AdaPT accuracy   : {cell['acc_adapt']:.3f}  "
          f"(delta {cell['delta']:+.3f})")
    print(f"  SU train={cell['SU_train']:.2f} infer={cell['SU_infer']:.2f} "
          f"SZ={cell['SZ']:.2f} MEM={cell['MEM']:.2f}")
    print(f"  avg WL={cell['avg_wl']:.1f} avg nonzero={cell['avg_sp']:.2f}")

    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(
        args.out, f"wl_trajectory_{args.arch}_c{args.classes}.csv")
    traj = cell["wl_trajectory"]
    if traj:
        with open(path, "w", newline="") as f:
            w = csv.writer(f)
            layers = sorted(traj[0])
            w.writerow(["switch"] + layers)
            for i, s in enumerate(traj):
                w.writerow([i] + [f"{s[l]:.1f}" for l in layers])
        print(f"  WL trajectories (fig. 3/4 data) -> {path}")


if __name__ == "__main__":
    main()
